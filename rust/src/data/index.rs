//! Memory-bounded dataset access: the header-only [`DatasetIndex`] and
//! the byte-budgeted LRU [`BlockCache`] loader workers read through.
//!
//! The pre-PR-4 data plane materialized the whole corpus in RAM
//! (`load_dataset` → `Arc<Vec<Sample>>`), which cannot scale to the
//! paper's 202M-sample / ~2 TB corpus. This module replaces residency
//! with addressing:
//!
//!  * [`DatasetIndex::open`] reads only each shard's 16-byte header and
//!    maps a global sample id → (shard, local index). Opening a 2 TB
//!    corpus costs a few KB of metadata.
//!  * [`BlockCache`] serves `get(id)` by reading ~[`BLOCK_BYTES`]-sized
//!    contiguous sample blocks from disk and keeping at most
//!    `data.cache_mb` of them resident (strict LRU, evicted by bytes,
//!    minimum one block so a tiny budget still makes progress).
//!
//! Resident dataset memory is therefore O(cache budget), not O(corpus):
//! the trainer's working set is `cache_mb + loaders·shuffle_window·4B +
//! prefetch·batch` regardless of dataset size. Counters for bytes read,
//! hits/misses and IO wait feed [`super::loader::LoaderStats`] and from
//! there the per-step report columns.
//!
//! concurrency invariant: the [`IoStats`] atomics are monotonic stat
//! counters accessed `Relaxed` — telemetry only, never used to publish
//! memory. The cache's shared state is protected by its inner mutex.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{ensure, Context};

use super::records::{Sample, ShardReader, HEADER_BYTES};
use crate::Result;

/// Target contiguous read size per cache block. Large enough to
/// amortize seeks on both SSD and Lustre, small enough that a handful
/// of blocks fit in even a deliberately tiny test cache.
pub const BLOCK_BYTES: u64 = 256 * 1024;

/// IO/cache counters shared between the block cache and the loader
/// stats (u64 atomics — see `LoaderStats` for the 32-bit rationale).
#[derive(Debug, Default)]
pub struct IoStats {
    /// Bytes actually read from disk (block fetches).
    pub bytes_read: AtomicU64,
    /// `get` calls served from a resident block.
    pub cache_hits: AtomicU64,
    /// `get` calls that had to fetch a block.
    pub cache_misses: AtomicU64,
    /// Wall time spent inside block fetches, nanoseconds.
    pub io_wait_ns: AtomicU64,
    /// `get` calls whose sample lives in the same block as the calling
    /// worker's previous lookup — contention the run-based worker
    /// affinity avoided (the block was already this worker's, no other
    /// worker raced to fetch it). Counted by the loader, not the cache.
    pub affine_hits: AtomicU64,
    /// Blocks fetched ahead of demand by [`BlockCache::warm`]. Warm
    /// fetches count toward `bytes_read`/`io_wait_ns` but not
    /// hits/misses, so `hit_rate` keeps measuring demand traffic only.
    pub prefetched_blocks: AtomicU64,
}

impl IoStats {
    /// Fraction of lookups served without touching disk. A window with
    /// no lookups reports 1.0 (nothing was missed).
    pub fn hit_rate(&self) -> f64 {
        // ord: Relaxed — advisory counters; a read racing an update
        // is off by at most one lookup
        let h = self.cache_hits.load(Ordering::Relaxed) as f64;
        let m = self.cache_misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 { 1.0 } else { h / (h + m) }
    }

    /// Snapshot (bytes_read, hits, misses, io_wait_ns) for delta
    /// accounting across steps.
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        // ord: Relaxed — the four counters are not mutually
        // consistent and callers only compute per-step deltas
        (
            self.bytes_read.load(Ordering::Relaxed),
            self.cache_hits.load(Ordering::Relaxed),
            self.cache_misses.load(Ordering::Relaxed),
            self.io_wait_ns.load(Ordering::Relaxed),
        )
    }
}

/// Per-shard metadata gathered header-only.
#[derive(Clone, Debug)]
pub struct ShardMeta {
    pub path: PathBuf,
    /// Samples in this shard.
    pub count: u64,
    /// Global id of this shard's first sample.
    pub base: u64,
}

/// Global sample id → (shard, offset) map over a set of shard files,
/// built without decoding a single sample.
#[derive(Debug)]
pub struct DatasetIndex {
    shards: Vec<ShardMeta>,
    seq: usize,
    total: u64,
}

impl DatasetIndex {
    /// Open every shard header-only; validates magic/version/count
    /// bounds (via [`ShardReader::open`]) and uniform sequence length.
    pub fn open(paths: &[PathBuf]) -> Result<DatasetIndex> {
        ensure!(!paths.is_empty(), "no shards to index");
        // bounded: one metadata entry per caller-supplied shard path
        let mut shards = Vec::with_capacity(paths.len());
        let mut seq = 0usize;
        let mut total = 0u64;
        for p in paths {
            let r = ShardReader::open(p)?;
            ensure!(seq == 0 || seq == r.seq,
                    "mixed sequence lengths: shard {} has seq {}, \
                     expected {seq}", p.display(), r.seq);
            seq = r.seq;
            shards.push(ShardMeta {
                path: p.clone(),
                count: r.len() as u64,
                base: total,
            });
            total += r.len() as u64;
        }
        ensure!(total > 0, "indexed shards hold no samples");
        Ok(DatasetIndex { shards, seq, total })
    }

    /// Total samples across all shards.
    pub fn len(&self) -> usize {
        self.total as usize
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn shards(&self) -> &[ShardMeta] {
        &self.shards
    }

    /// Per-shard sample counts (the windowed shuffle's level-1 input).
    pub fn shard_counts(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.count).collect()
    }

    /// Total on-disk footprint of the shard set, headers included —
    /// the volume staging copies and the staging cost model prices.
    pub fn total_bytes(&self) -> u64 {
        self.total * Sample::disk_bytes(self.seq)
            + self.shards.len() as u64 * HEADER_BYTES
    }

    /// Map a global sample id to (shard index, index within shard).
    pub fn locate(&self, id: u64) -> Result<(usize, u64)> {
        ensure!(id < self.total,
                "sample id {id} outside dataset of {} samples",
                self.total);
        // binary search over shard bases
        let shard = self
            .shards
            .partition_point(|s| s.base <= id)
            .saturating_sub(1);
        Ok((shard, id - self.shards[shard].base))
    }
}

/// One resident cache block: decoded samples + LRU tick + byte cost.
struct Block {
    samples: Vec<Sample>,
    bytes: u64,
    tick: u64,
}

#[derive(Default)]
struct CacheInner {
    blocks: HashMap<(u32, u32), Block>,
    resident_bytes: u64,
    tick: u64,
    /// Most-recently-used open shard file. Rank segments are
    /// contiguous, so consecutive misses overwhelmingly hit the same
    /// shard — keeping one reader open avoids re-opening (and
    /// re-validating) the file on every block fetch while costing one
    /// fd per rank.
    reader: Option<(usize, ShardReader)>,
}

/// Byte-budgeted LRU block cache over a [`DatasetIndex`]. `get(id)`
/// reads through disk in ~[`BLOCK_BYTES`] contiguous blocks; at most
/// `cache_mb` MiB of decoded samples stay resident (always at least one
/// block, so a 1-block cache degenerates to "re-read on every block
/// switch" and still terminates).
///
/// Shared by all loader workers of a rank. Fetches happen under the
/// cache lock: concurrent workers asking for the same cold block do one
/// disk read, not N — serializing IO per rank the way a real per-node
/// page cache would.
pub struct BlockCache {
    index: std::sync::Arc<DatasetIndex>,
    block_samples: u64,
    budget_bytes: u64,
    inner: Mutex<CacheInner>,
}

impl BlockCache {
    pub fn new(index: std::sync::Arc<DatasetIndex>, cache_mb: f64)
        -> Result<BlockCache> {
        ensure!(cache_mb.is_finite() && cache_mb > 0.0,
                "cache_mb must be positive and finite (got {cache_mb})");
        let sample_bytes = Sample::disk_bytes(index.seq());
        let block_samples = (BLOCK_BYTES / sample_bytes).max(1);
        let budget_bytes = (cache_mb * 1024.0 * 1024.0) as u64;
        Ok(BlockCache { index, block_samples, budget_bytes, inner:
            Mutex::new(CacheInner::default()) })
    }

    /// Samples per (full) block — exposed for the perf model and tests.
    pub fn block_samples(&self) -> usize {
        self.block_samples as usize
    }

    /// The index this cache reads through.
    pub fn dataset(&self) -> &DatasetIndex {
        &self.index
    }

    /// Current resident payload bytes (tests assert the budget holds).
    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().resident_bytes
    }

    /// Fetch one sample by global id, reading (and caching) its block
    /// on a miss. Counters land in `io`.
    pub fn get(&self, id: u64, io: &IoStats) -> Result<Sample> {
        let (shard, local) = self.index.locate(id)?;
        let block = local / self.block_samples;
        let key = (shard as u32, block as u32);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(b) = inner.blocks.get_mut(&key) {
            b.tick = tick;
            // ord: Relaxed — monotonic stat counters (here and below);
            // the cache itself is serialized by `inner`'s mutex
            io.cache_hits.fetch_add(1, Ordering::Relaxed);
            let off = (local - block * self.block_samples) as usize;
            return Ok(b.samples[off].clone());
        }
        io.cache_misses.fetch_add(1, Ordering::Relaxed);
        self.fetch_block(&mut inner, shard, block, tick, io)?;
        let off = (local - block * self.block_samples) as usize;
        let b = inner
            .blocks
            .get(&key)
            .expect("the block just fetched is never the eviction victim");
        Ok(b.samples[off].clone())
    }

    /// Prefetch: make sure sample `id`'s block is resident, fetching it
    /// on absence — no sample is cloned out. Returns whether a disk
    /// read happened. An already-resident block is left untouched: its
    /// LRU tick is NOT refreshed, so prefetch probes never shadow
    /// demand recency in the eviction order.
    pub fn warm(&self, id: u64, io: &IoStats) -> Result<bool> {
        let (shard, local) = self.index.locate(id)?;
        let block = local / self.block_samples;
        let key = (shard as u32, block as u32);
        let mut inner = self.inner.lock().unwrap();
        if inner.blocks.contains_key(&key) {
            return Ok(false);
        }
        inner.tick += 1;
        let tick = inner.tick;
        self.fetch_block(&mut inner, shard, block, tick, io)?;
        // ord: Relaxed — monotonic stat counter, telemetry only
        io.prefetched_blocks.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    /// The (shard, block) cache key sample `id` lives in — pure index
    /// arithmetic, no lock taken. Loader workers use it to count
    /// affinity streaks without serializing on the cache.
    pub fn block_of(&self, id: u64) -> Result<(u32, u32)> {
        let (shard, local) = self.index.locate(id)?;
        Ok((shard as u32, (local / self.block_samples) as u32))
    }

    /// Read block (`shard`, `block`) from disk into the cache under the
    /// already-held lock, then evict LRU down to budget. Shared by the
    /// demand-miss path of [`BlockCache::get`] and the prefetch path of
    /// [`BlockCache::warm`], so the two can never drift in accounting
    /// or eviction policy.
    fn fetch_block(&self, inner: &mut CacheInner, shard: usize,
                   block: u64, tick: u64, io: &IoStats) -> Result<()> {
        let key = (shard as u32, block as u32);
        let meta = &self.index.shards()[shard];
        let start = block * self.block_samples;
        let n = self.block_samples.min(meta.count - start);
        let t0 = Instant::now();
        let mut reader = match inner.reader.take() {
            Some((s, r)) if s == shard => r,
            _ => ShardReader::open(&meta.path)?,
        };
        let samples = reader
            .read_block(start as usize, n as usize)
            .with_context(|| {
                format!("fetching block {block} of {}", meta.path.display())
            })?;
        inner.reader = Some((shard, reader));
        // ord: Relaxed — same advisory-counter contract as above
        io.io_wait_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let bytes = n * Sample::disk_bytes(self.index.seq());
        io.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        inner.resident_bytes += bytes;
        inner.blocks.insert(key, Block { samples, bytes, tick });
        // strict LRU eviction by bytes; always keep the block we just
        // inserted so a sub-block budget still makes progress
        while inner.resident_bytes > self.budget_bytes
            && inner.blocks.len() > 1
        {
            let oldest = inner
                .blocks
                .iter()
                .filter(|(k, _)| **k != key)
                .min_by_key(|(_, b)| b.tick)
                .map(|(k, _)| *k)
                .unwrap();
            if let Some(b) = inner.blocks.remove(&oldest) {
                inner.resident_bytes -= b.bytes;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ShardWriter;
    use std::sync::Arc;

    fn write_shards(tag: &str, counts: &[usize], seq: usize)
        -> (PathBuf, Vec<PathBuf>, Vec<Sample>) {
        let dir = std::env::temp_dir()
            .join(format!("txgain-index-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut paths = Vec::new();
        let mut all = Vec::new();
        let mut id = 0u16;
        for (si, &n) in counts.iter().enumerate() {
            let p = dir.join(format!("s{si}.bin"));
            let mut w = ShardWriter::create(&p, seq).unwrap();
            for _ in 0..n {
                let s = Sample::from_tokens(&[id, id.wrapping_add(1)], seq);
                w.write(&s).unwrap();
                all.push(s);
                id = id.wrapping_add(1);
            }
            w.finish().unwrap();
            paths.push(p);
        }
        (dir, paths, all)
    }

    #[test]
    fn index_maps_ids_across_shards() {
        let (dir, paths, all) = write_shards("map", &[5, 1, 7], 8);
        let idx = DatasetIndex::open(&paths).unwrap();
        assert_eq!(idx.len(), 13);
        assert_eq!(idx.seq(), 8);
        assert_eq!(idx.shard_counts(), vec![5, 1, 7]);
        assert_eq!(idx.locate(0).unwrap(), (0, 0));
        assert_eq!(idx.locate(4).unwrap(), (0, 4));
        assert_eq!(idx.locate(5).unwrap(), (1, 0));
        assert_eq!(idx.locate(6).unwrap(), (2, 0));
        assert_eq!(idx.locate(12).unwrap(), (2, 6));
        assert!(idx.locate(13).is_err());
        // and the bytes accounting matches the files on disk
        let disk: u64 = paths.iter()
            .map(|p| std::fs::metadata(p).unwrap().len()).sum();
        assert_eq!(idx.total_bytes(), disk);
        let _ = all;
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cache_serves_every_sample_correctly() {
        let (dir, paths, all) = write_shards("serve", &[64, 32, 100], 16);
        let idx = Arc::new(DatasetIndex::open(&paths).unwrap());
        let cache = BlockCache::new(idx.clone(), 64.0).unwrap();
        let io = IoStats::default();
        // random-ish access pattern over the whole corpus
        for k in 0..idx.len() {
            let id = (k * 97) % idx.len();
            assert_eq!(cache.get(id as u64, &io).unwrap(), all[id],
                       "id {id}");
        }
        assert!(io.bytes_read.load(Ordering::Relaxed) > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn one_block_cache_thrashes_but_stays_correct_and_bounded() {
        let seq = 16; // sample = 34 B; block = 256 KiB / 34 ≈ 7710 — one
                      // block spans each whole small shard here
        let (dir, paths, all) = write_shards("thrash", &[40, 40], seq);
        let idx = Arc::new(DatasetIndex::open(&paths).unwrap());
        // budget below one block: capacity clamps to a single block
        let cache = BlockCache::new(idx.clone(), 0.001).unwrap();
        let io = IoStats::default();
        // alternate shards every access: every get crosses blocks
        for k in 0..40 {
            for s in 0..2u64 {
                let id = s * 40 + k as u64;
                assert_eq!(cache.get(id, &io).unwrap(), all[id as usize]);
            }
        }
        let shard_bytes = 40 * Sample::disk_bytes(seq);
        assert!(cache.resident_bytes() <= shard_bytes,
                "resident {} > one block {}", cache.resident_bytes(),
                shard_bytes);
        // thrash: ~every access that switched shards was a miss
        let misses = io.cache_misses.load(Ordering::Relaxed);
        assert!(misses >= 79, "expected hard thrashing, misses={misses}");
        assert_eq!(io.bytes_read.load(Ordering::Relaxed),
                   misses * shard_bytes);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_prefetches_blocks_without_demand_misses() {
        let (dir, paths, all) = write_shards("prefetch", &[30], 16);
        let idx = Arc::new(DatasetIndex::open(&paths).unwrap());
        let cache = BlockCache::new(idx, 64.0).unwrap();
        let io = IoStats::default();
        for id in 0..30u64 {
            cache.warm(id, &io).unwrap();
        }
        assert!(io.prefetched_blocks.load(Ordering::Relaxed) >= 1);
        assert!(io.bytes_read.load(Ordering::Relaxed) > 0);
        // warming is not a demand lookup
        assert_eq!(io.cache_misses.load(Ordering::Relaxed), 0);
        let warmed = io.bytes_read.load(Ordering::Relaxed);
        // demand reads are now pure hits: no further disk traffic
        for id in 0..30u64 {
            assert_eq!(cache.get(id, &io).unwrap(), all[id as usize]);
        }
        assert_eq!(io.bytes_read.load(Ordering::Relaxed), warmed);
        assert_eq!(io.cache_misses.load(Ordering::Relaxed), 0);
        assert_eq!(io.hit_rate(), 1.0);
        // warming a resident block is a no-op
        assert!(!cache.warm(0, &io).unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn block_of_matches_cache_addressing() {
        // seq 16 → a block spans thousands of samples, so both 40-sample
        // shards are single-block: ids share a key within a shard and
        // change it at the shard boundary
        let (dir, paths, _) = write_shards("blockof", &[40, 40], 16);
        let idx = Arc::new(DatasetIndex::open(&paths).unwrap());
        let cache = BlockCache::new(idx, 64.0).unwrap();
        assert_eq!(cache.block_of(0).unwrap(),
                   cache.block_of(39).unwrap());
        assert_ne!(cache.block_of(0).unwrap(),
                   cache.block_of(40).unwrap());
        assert!(cache.block_of(80).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_cache_stops_reading_disk() {
        let (dir, paths, all) = write_shards("warm", &[30], 16);
        let idx = Arc::new(DatasetIndex::open(&paths).unwrap());
        let cache = BlockCache::new(idx, 64.0).unwrap();
        let io = IoStats::default();
        for id in 0..30u64 {
            cache.get(id, &io).unwrap();
        }
        let cold = io.bytes_read.load(Ordering::Relaxed);
        for id in 0..30u64 {
            assert_eq!(cache.get(id, &io).unwrap(), all[id as usize]);
        }
        assert_eq!(io.bytes_read.load(Ordering::Relaxed), cold,
                   "second pass must be disk-free");
        assert!(io.hit_rate() > 0.9);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
