//! MLM masking — "15% of tokens in the training dataset randomly
//! masked" (paper §II). BERT's 80/10/10 recipe:
//!   of the selected positions, 80% become [MASK], 10% a random token,
//!   10% keep the original token; the label is always the original id.
//!
//! Masking lives in the data pipeline (as in the paper), keeping the AOT
//! train step deterministic: the model consumes (ids, mask, labels).

use super::special::{BYTE_BASE, MASK};
use super::Sample;
use crate::util::Rng;

/// Ignored-position label (matches the python side's `label < 0` test).
pub const IGNORE: i32 = -100;

#[derive(Clone, Debug)]
pub struct Masker {
    pub mask_prob: f64,
    pub vocab: usize,
}

/// A masked sample ready for the model.
#[derive(Clone, Debug, PartialEq)]
pub struct MaskedSample {
    pub input_ids: Vec<i32>,
    pub attn_mask: Vec<f32>,
    pub labels: Vec<i32>,
}

impl Masker {
    pub fn new(mask_prob: f64, vocab: usize) -> Self {
        assert!((0.0..=1.0).contains(&mask_prob));
        assert!(vocab > BYTE_BASE as usize);
        Masker { mask_prob, vocab }
    }

    /// Apply MLM masking. `rng` should be derived per (epoch, sample) so
    /// masks differ across epochs but reproduce across runs.
    pub fn apply(&self, sample: &Sample, rng: &mut Rng) -> MaskedSample {
        let seq = sample.ids.len();
        let mut input_ids = Vec::with_capacity(seq);
        let mut attn_mask = Vec::with_capacity(seq);
        let mut labels = Vec::with_capacity(seq);
        for (pos, &id) in sample.ids.iter().enumerate() {
            let real = pos < sample.len as usize;
            attn_mask.push(if real { 1.0 } else { 0.0 });
            // never mask specials (PAD/CLS/SEP/MASK) or padding
            let maskable = real && id >= BYTE_BASE;
            if maskable && rng.next_f64() < self.mask_prob {
                labels.push(id as i32);
                let roll = rng.next_f64();
                if roll < 0.8 {
                    input_ids.push(MASK as i32);
                } else if roll < 0.9 {
                    // random *content* token (skip specials)
                    let span = (self.vocab - BYTE_BASE as usize) as u64;
                    input_ids.push(
                        (BYTE_BASE as u64 + rng.gen_range(span)) as i32,
                    );
                } else {
                    input_ids.push(id as i32);
                }
            } else {
                labels.push(IGNORE);
                input_ids.push(id as i32);
            }
        }
        MaskedSample { input_ids, attn_mask, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::special::{CLS, PAD, SEP};

    fn sample(seq: usize, len: usize) -> Sample {
        let mut ids = vec![CLS];
        ids.extend((0..len - 2).map(|i| BYTE_BASE + (i % 200) as u16));
        ids.push(SEP);
        Sample::from_tokens(&ids, seq)
    }

    #[test]
    fn mask_rate_close_to_config() {
        let m = Masker::new(0.15, 512);
        let mut rng = Rng::new(3);
        let mut masked = 0usize;
        let mut maskable = 0usize;
        for i in 0..200 {
            let s = sample(64, 60);
            let out = m.apply(&s, &mut rng.derive(&format!("s{i}")));
            for (pos, &l) in out.labels.iter().enumerate() {
                if pos < 60 && s.ids[pos] >= BYTE_BASE {
                    maskable += 1;
                    if l != IGNORE {
                        masked += 1;
                    }
                }
            }
        }
        let rate = masked as f64 / maskable as f64;
        assert!((rate - 0.15).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn labels_match_original_ids() {
        let m = Masker::new(0.5, 512);
        let s = sample(64, 60);
        let mut rng = Rng::new(9);
        let out = m.apply(&s, &mut rng);
        for (pos, &l) in out.labels.iter().enumerate() {
            if l != IGNORE {
                assert_eq!(l, s.ids[pos] as i32);
            }
        }
    }

    #[test]
    fn specials_and_padding_never_masked() {
        let m = Masker::new(1.0, 512); // mask everything maskable
        let s = sample(64, 32);
        let mut rng = Rng::new(4);
        let out = m.apply(&s, &mut rng);
        assert_eq!(out.labels[0], IGNORE); // CLS
        assert_eq!(out.labels[31], IGNORE); // SEP
        for pos in 32..64 {
            assert_eq!(out.labels[pos], IGNORE); // padding
            assert_eq!(out.attn_mask[pos], 0.0);
            assert_eq!(out.input_ids[pos], PAD as i32);
        }
    }

    #[test]
    fn eighty_ten_ten_split() {
        let m = Masker::new(1.0, 512);
        let mut rng = Rng::new(8);
        let (mut to_mask, mut random, mut kept, mut total) = (0, 0, 0, 0);
        for i in 0..300 {
            let s = sample(64, 62);
            let out = m.apply(&s, &mut rng.derive(&format!("b{i}")));
            for (pos, &l) in out.labels.iter().enumerate() {
                if l == IGNORE {
                    continue;
                }
                total += 1;
                let inp = out.input_ids[pos];
                if inp == MASK as i32 {
                    to_mask += 1;
                } else if inp == l {
                    kept += 1;
                } else {
                    random += 1;
                }
            }
        }
        let f = |x: i32| x as f64 / total as f64;
        assert!((f(to_mask) - 0.8).abs() < 0.03, "mask={}", f(to_mask));
        assert!((f(random) - 0.1).abs() < 0.02, "rand={}", f(random));
        assert!((f(kept) - 0.1).abs() < 0.02, "kept={}", f(kept));
    }

    #[test]
    fn deterministic_given_rng_stream() {
        let m = Masker::new(0.15, 512);
        let s = sample(32, 30);
        let a = m.apply(&s, &mut Rng::new(42));
        let b = m.apply(&s, &mut Rng::new(42));
        assert_eq!(a, b);
    }
}
