//! Packed shard format — the post-preprocessing on-disk representation
//! (recommendation 1: "store only the necessary training data: tokenized
//! inputs and attention masks").
//!
//! Layout (little-endian):
//! ```text
//! magic   u32  = 0x54584753 ("TXGS")
//! version u32  = 1
//! count   u32    samples in this shard
//! seq     u32    fixed sequence length
//! then per sample:
//!   len   u16    number of real (non-pad) tokens, <= seq
//!   ids   u16[seq]  token ids, PAD-filled past `len`
//! ```
//! The attention mask is just `pos < len`, so it costs 2 bytes per
//! sample instead of `seq` — part of the 99 % reduction story.
//!
//! Readers are *streaming*: [`ShardReader::open`] reads only the 16-byte
//! header (bounding the claimed `count` against the actual file size, so
//! a corrupt header can never drive a huge allocation), and samples are
//! fetched on demand with [`ShardReader::get`] / [`ShardReader::read_block`]
//! — random access for the block cache, one contiguous read per block.
//! [`ShardReader::read_all`] materializes a whole shard for callers that
//! genuinely want it in memory (tests, the equivalence reference path).

use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context};

use super::special::PAD;
use crate::Result;

pub const MAGIC: u32 = 0x5458_4753;
pub const VERSION: u32 = 1;

/// Header size in bytes (magic, version, count, seq).
pub const HEADER_BYTES: u64 = 16;

/// One preprocessed sample: fixed-length ids + real length.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub ids: Vec<u16>,
    pub len: u16,
}

impl Sample {
    /// Build from unpadded tokens, truncating/padding to `seq`.
    pub fn from_tokens(tokens: &[u16], seq: usize) -> Sample {
        let len = tokens.len().min(seq);
        // bounded: seq is the caller's configured sequence length, not
        // a wire- or file-derived value
        let mut ids = Vec::with_capacity(seq);
        ids.extend_from_slice(&tokens[..len]);
        ids.resize(seq, PAD);
        Sample { ids, len: len as u16 }
    }

    /// Serialized size of one sample at sequence length `seq`.
    pub fn disk_bytes(seq: usize) -> u64 {
        2 + 2 * seq as u64
    }
}

/// Streaming shard writer.
pub struct ShardWriter {
    out: BufWriter<std::fs::File>,
    seq: u32,
    count: u32,
    path: std::path::PathBuf,
}

impl ShardWriter {
    pub fn create(path: &Path, seq: usize) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating shard {}", path.display()))?;
        let mut out = BufWriter::new(f);
        out.write_all(&MAGIC.to_le_bytes())?;
        out.write_all(&VERSION.to_le_bytes())?;
        out.write_all(&0u32.to_le_bytes())?; // count patched on finish
        out.write_all(&(seq as u32).to_le_bytes())?;
        Ok(ShardWriter { out, seq: seq as u32, count: 0,
                         path: path.to_path_buf() })
    }

    pub fn write(&mut self, sample: &Sample) -> Result<()> {
        ensure!(sample.ids.len() == self.seq as usize,
                "sample seq {} != shard seq {}", sample.ids.len(), self.seq);
        self.out.write_all(&sample.len.to_le_bytes())?;
        // bulk-write ids as LE u16
        // bounded: sized from the in-memory sample being written
        let mut buf = Vec::with_capacity(sample.ids.len() * 2);
        for id in &sample.ids {
            buf.extend_from_slice(&id.to_le_bytes());
        }
        self.out.write_all(&buf)?;
        self.count += 1;
        Ok(())
    }

    /// Flush and patch the sample count into the header.
    pub fn finish(mut self) -> Result<u64> {
        self.out.flush()?;
        let f = self.out.into_inner()?;
        drop(f);
        // patch count at offset 8
        let mut f = std::fs::OpenOptions::new().write(true)
            .open(&self.path)?;
        f.seek(SeekFrom::Start(8))?;
        f.write_all(&self.count.to_le_bytes())?;
        f.sync_all()?;
        Ok(std::fs::metadata(&self.path)?.len())
    }
}

/// Decode one serialized sample (`len u16` + `seq` LE u16 ids).
fn decode_sample(buf: &[u8], seq: usize) -> Result<Sample> {
    let len = u16::from_le_bytes(buf[0..2].try_into().unwrap());
    ensure!(len as usize <= seq, "corrupt sample: len {len} > seq {seq}");
    let ids: Vec<u16> = buf[2..]
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Sample { ids, len })
}

/// Random-access shard reader. `open` touches only the header; samples
/// are read from disk on demand. Hardened against corrupt headers: the
/// claimed sample count is bounded by what the file can actually hold
/// before any allocation, so truncated or garbage files fail cleanly.
pub struct ShardReader {
    pub seq: usize,
    count: usize,
    file: std::fs::File,
}

impl ShardReader {
    pub fn open(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening shard {}", path.display()))?;
        let file_bytes = file.metadata()?.len();
        let mut h = [0u8; HEADER_BYTES as usize];
        (&file).read_exact(&mut h).context("shard header")?;
        let magic = u32::from_le_bytes(h[0..4].try_into().unwrap());
        let version = u32::from_le_bytes(h[4..8].try_into().unwrap());
        let count = u32::from_le_bytes(h[8..12].try_into().unwrap());
        let seq = u32::from_le_bytes(h[12..16].try_into().unwrap()) as usize;
        if magic != MAGIC {
            bail!("not a txgain shard (bad magic {magic:#x})");
        }
        if version != VERSION {
            bail!("unsupported shard version {version}");
        }
        ensure!(seq > 0, "corrupt shard header: seq 0");
        // bound the claimed count by what the file can actually hold —
        // a corrupt header must fail here, not in a huge allocation or
        // a short read deep inside an epoch
        let payload = file_bytes.saturating_sub(HEADER_BYTES);
        let holds = payload / Sample::disk_bytes(seq);
        ensure!(u64::from(count) <= holds,
                "corrupt shard {}: header claims {count} samples but the \
                 file holds at most {holds}", path.display());
        Ok(ShardReader { seq, count: count as usize, file })
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Byte offset of sample `i` within the shard file.
    fn offset(&self, i: usize) -> u64 {
        HEADER_BYTES + i as u64 * Sample::disk_bytes(self.seq)
    }

    /// Read one sample by index (random access).
    pub fn get(&mut self, i: usize) -> Result<Sample> {
        Ok(self.read_block(i, 1)?.pop().unwrap())
    }

    /// Read `n` consecutive samples starting at `start` in ONE
    /// contiguous disk read (the block cache's fetch unit). `start + n`
    /// must be within the shard.
    pub fn read_block(&mut self, start: usize, n: usize)
        -> Result<Vec<Sample>> {
        ensure!(start + n <= self.count,
                "block [{start}, {}) outside shard of {} samples",
                start + n, self.count);
        let sample_bytes = Sample::disk_bytes(self.seq) as usize;
        // bounded: start + n ≤ count (checked above) and count was
        // validated against the file's real payload size in `open`
        let mut buf = vec![0u8; n * sample_bytes];
        self.file.seek(SeekFrom::Start(self.offset(start)))?;
        self.file.read_exact(&mut buf).with_context(|| {
            format!("truncated shard payload reading samples \
                     [{start}, {})", start + n)
        })?;
        buf.chunks_exact(sample_bytes)
            .map(|c| decode_sample(c, self.seq))
            .collect()
    }

    /// Materialize the whole shard (the in-memory reference path).
    pub fn read_all(&mut self) -> Result<Vec<Sample>> {
        if self.count == 0 {
            return Ok(Vec::new());
        }
        // buffered sequential read: one pass, still bounds-checked
        let sample_bytes = Sample::disk_bytes(self.seq) as usize;
        self.file.seek(SeekFrom::Start(HEADER_BYTES))?;
        let mut r = BufReader::new(&self.file);
        // bounded: one sample's bytes; count was validated against the
        // file's real payload size in `open`
        let mut buf = vec![0u8; sample_bytes];
        let mut out = Vec::with_capacity(self.count);
        for i in 0..self.count {
            r.read_exact(&mut buf).with_context(|| {
                format!("truncated shard payload at sample {i}")
            })?;
            out.push(decode_sample(&buf, self.seq)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        let pid = std::process::id();
        std::env::temp_dir().join(format!("txgain-test-{pid}-{tag}.shard"))
    }

    fn write_samples(path: &Path, seq: usize, samples: &[Sample]) -> u64 {
        let mut w = ShardWriter::create(path, seq).unwrap();
        for s in samples {
            w.write(s).unwrap();
        }
        w.finish().unwrap()
    }

    fn gen_samples(n: usize, seq: usize, seed: u64) -> Vec<Sample> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let len = 1 + rng.gen_range(40) as usize;
                let toks: Vec<u16> =
                    (0..len).map(|_| rng.gen_range(500) as u16).collect();
                Sample::from_tokens(&toks, seq)
            })
            .collect()
    }

    #[test]
    fn roundtrip() {
        let path = tmpfile("roundtrip");
        let seq = 32;
        let samples = gen_samples(17, seq, 1);
        let bytes = write_samples(&path, seq, &samples);
        assert_eq!(bytes, 16 + 17 * Sample::disk_bytes(seq));

        let mut r = ShardReader::open(&path).unwrap();
        assert_eq!(r.seq, seq);
        assert_eq!(r.len(), 17);
        assert_eq!(r.read_all().unwrap(), samples);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn random_access_matches_sequential() {
        let path = tmpfile("randacc");
        let seq = 24;
        let samples = gen_samples(23, seq, 5);
        write_samples(&path, seq, &samples);
        let mut r = ShardReader::open(&path).unwrap();
        // out-of-order single gets
        for &i in &[7usize, 0, 22, 13, 7] {
            assert_eq!(r.get(i).unwrap(), samples[i], "sample {i}");
        }
        // block reads, including the tail
        assert_eq!(r.read_block(4, 6).unwrap(), &samples[4..10]);
        assert_eq!(r.read_block(20, 3).unwrap(), &samples[20..23]);
        // out-of-bounds block is a clean error
        assert!(r.read_block(21, 3).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn from_tokens_pads_and_truncates() {
        let s = Sample::from_tokens(&[10, 11, 12], 5);
        assert_eq!(s.ids, vec![10, 11, 12, PAD, PAD]);
        assert_eq!(s.len, 3);
        let s = Sample::from_tokens(&[1, 2, 3, 4, 5, 6, 7], 4);
        assert_eq!(s.ids, vec![1, 2, 3, 4]);
        assert_eq!(s.len, 4);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("badmagic");
        std::fs::write(&path, b"NOPEnope0000aaaa").unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_count_beyond_file_size() {
        // a valid header whose count claims far more samples than the
        // file holds must fail at open (bounded before any allocation),
        // not OOM or error mid-epoch
        let path = tmpfile("hugecount");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // count
        bytes.extend_from_slice(&64u32.to_le_bytes()); // seq
        bytes.extend_from_slice(&[0u8; 130]); // exactly one sample
        std::fs::write(&path, &bytes).unwrap();
        let err = ShardReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("holds at most"), "unexpected: {err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_payload_fails_cleanly() {
        // truncate a valid shard mid-payload: open still succeeds only
        // if the header count fits the remaining bytes; here it does
        // not, so the bound check reports it up front
        let path = tmpfile("truncpay");
        let seq = 16;
        let samples = gen_samples(10, seq, 9);
        write_samples(&path, seq, &samples);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 7]).unwrap();
        let err = ShardReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("holds at most"), "unexpected: {err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn zero_seq_header_is_rejected() {
        // seq 0 would make disk_bytes tiny and the count bound useless;
        // reject it explicitly (also avoids a divide-by-zero flavor of
        // bug in downstream block math)
        let path = tmpfile("zeroseq");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_wrong_seq_on_write() {
        let path = tmpfile("wrongseq");
        let mut w = ShardWriter::create(&path, 8).unwrap();
        let s = Sample::from_tokens(&[1, 2], 16);
        assert!(w.write(&s).is_err());
        drop(w);
        std::fs::remove_file(&path).unwrap();
    }
}
