//! Packed shard format — the post-preprocessing on-disk representation
//! (recommendation 1: "store only the necessary training data: tokenized
//! inputs and attention masks").
//!
//! Layout (little-endian):
//! ```text
//! magic   u32  = 0x54584753 ("TXGS")
//! version u32  = 1
//! count   u32    samples in this shard
//! seq     u32    fixed sequence length
//! then per sample:
//!   len   u16    number of real (non-pad) tokens, <= seq
//!   ids   u16[seq]  token ids, PAD-filled past `len`
//! ```
//! The attention mask is just `pos < len`, so it costs 2 bytes per
//! sample instead of `seq` — part of the 99 % reduction story.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context};

use super::special::PAD;
use crate::Result;

pub const MAGIC: u32 = 0x5458_4753;
pub const VERSION: u32 = 1;

/// One preprocessed sample: fixed-length ids + real length.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub ids: Vec<u16>,
    pub len: u16,
}

impl Sample {
    /// Build from unpadded tokens, truncating/padding to `seq`.
    pub fn from_tokens(tokens: &[u16], seq: usize) -> Sample {
        let len = tokens.len().min(seq);
        let mut ids = Vec::with_capacity(seq);
        ids.extend_from_slice(&tokens[..len]);
        ids.resize(seq, PAD);
        Sample { ids, len: len as u16 }
    }

    /// Serialized size of one sample at sequence length `seq`.
    pub fn disk_bytes(seq: usize) -> u64 {
        2 + 2 * seq as u64
    }
}

/// Streaming shard writer.
pub struct ShardWriter {
    out: BufWriter<std::fs::File>,
    seq: u32,
    count: u32,
    path: std::path::PathBuf,
}

impl ShardWriter {
    pub fn create(path: &Path, seq: usize) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating shard {}", path.display()))?;
        let mut out = BufWriter::new(f);
        out.write_all(&MAGIC.to_le_bytes())?;
        out.write_all(&VERSION.to_le_bytes())?;
        out.write_all(&0u32.to_le_bytes())?; // count patched on finish
        out.write_all(&(seq as u32).to_le_bytes())?;
        Ok(ShardWriter { out, seq: seq as u32, count: 0,
                         path: path.to_path_buf() })
    }

    pub fn write(&mut self, sample: &Sample) -> Result<()> {
        ensure!(sample.ids.len() == self.seq as usize,
                "sample seq {} != shard seq {}", sample.ids.len(), self.seq);
        self.out.write_all(&sample.len.to_le_bytes())?;
        // bulk-write ids as LE u16
        let mut buf = Vec::with_capacity(sample.ids.len() * 2);
        for id in &sample.ids {
            buf.extend_from_slice(&id.to_le_bytes());
        }
        self.out.write_all(&buf)?;
        self.count += 1;
        Ok(())
    }

    /// Flush and patch the sample count into the header.
    pub fn finish(mut self) -> Result<u64> {
        self.out.flush()?;
        let f = self.out.into_inner()?;
        drop(f);
        // patch count at offset 8
        use std::io::{Seek, SeekFrom};
        let mut f = std::fs::OpenOptions::new().write(true)
            .open(&self.path)?;
        f.seek(SeekFrom::Start(8))?;
        f.write_all(&self.count.to_le_bytes())?;
        f.sync_all()?;
        Ok(std::fs::metadata(&self.path)?.len())
    }
}

/// In-memory shard reader (shards are sized to fit comfortably).
pub struct ShardReader {
    pub seq: usize,
    pub samples: Vec<Sample>,
}

impl ShardReader {
    pub fn open(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening shard {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut h = [0u8; 16];
        r.read_exact(&mut h).context("shard header")?;
        let magic = u32::from_le_bytes(h[0..4].try_into().unwrap());
        let version = u32::from_le_bytes(h[4..8].try_into().unwrap());
        let count = u32::from_le_bytes(h[8..12].try_into().unwrap());
        let seq = u32::from_le_bytes(h[12..16].try_into().unwrap()) as usize;
        if magic != MAGIC {
            bail!("not a txgain shard (bad magic {magic:#x})");
        }
        if version != VERSION {
            bail!("unsupported shard version {version}");
        }
        let mut samples = Vec::with_capacity(count as usize);
        let mut buf = vec![0u8; 2 + 2 * seq];
        for _ in 0..count {
            r.read_exact(&mut buf)?;
            let len = u16::from_le_bytes(buf[0..2].try_into().unwrap());
            ensure!(len as usize <= seq, "corrupt sample: len > seq");
            let ids: Vec<u16> = buf[2..]
                .chunks_exact(2)
                .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                .collect();
            samples.push(Sample { ids, len });
        }
        Ok(ShardReader { seq, samples })
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        let pid = std::process::id();
        std::env::temp_dir().join(format!("txgain-test-{pid}-{tag}.shard"))
    }

    #[test]
    fn roundtrip() {
        let path = tmpfile("roundtrip");
        let seq = 32;
        let mut rng = Rng::new(1);
        let samples: Vec<Sample> = (0..17)
            .map(|_| {
                let n = 1 + rng.gen_range(40) as usize;
                let toks: Vec<u16> =
                    (0..n).map(|_| rng.gen_range(500) as u16).collect();
                Sample::from_tokens(&toks, seq)
            })
            .collect();
        let mut w = ShardWriter::create(&path, seq).unwrap();
        for s in &samples {
            w.write(s).unwrap();
        }
        let bytes = w.finish().unwrap();
        assert_eq!(bytes, 16 + 17 * Sample::disk_bytes(seq));

        let r = ShardReader::open(&path).unwrap();
        assert_eq!(r.seq, seq);
        assert_eq!(r.samples, samples);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn from_tokens_pads_and_truncates() {
        let s = Sample::from_tokens(&[10, 11, 12], 5);
        assert_eq!(s.ids, vec![10, 11, 12, PAD, PAD]);
        assert_eq!(s.len, 3);
        let s = Sample::from_tokens(&[1, 2, 3, 4, 5, 6, 7], 4);
        assert_eq!(s.ids, vec![1, 2, 3, 4]);
        assert_eq!(s.len, 4);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("badmagic");
        std::fs::write(&path, b"NOPEnope0000aaaa").unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_wrong_seq_on_write() {
        let path = tmpfile("wrongseq");
        let mut w = ShardWriter::create(&path, 8).unwrap();
        let s = Sample::from_tokens(&[1, 2], 16);
        assert!(w.write(&s).is_err());
        drop(w);
        std::fs::remove_file(&path).unwrap();
    }
}
