//! Byte-level BPE tokenizer for binary code.
//!
//! The paper tokenizes compiled functions; bytes are the natural base
//! alphabet for machine code. Vocabulary layout (see [`super::special`]):
//! ids 0–3 special, 4–259 raw bytes, 260+ learned merges.
//!
//! Training is classic BPE: repeatedly merge the most frequent adjacent
//! pair over a (deterministic) sample of the corpus. Encoding applies
//! merges in rank order. Both are exact inverses: `decode(encode(x)) == x`
//! for arbitrary byte strings — property-tested below.

use std::collections::HashMap;

use anyhow::{bail, ensure};

use super::special::{BYTE_BASE, MERGE_BASE};
use crate::util::json::{self, Value};
use crate::Result;

#[derive(Clone, Debug)]
pub struct BpeTokenizer {
    /// merges[i] = (left, right) producing id MERGE_BASE + i.
    merges: Vec<(u16, u16)>,
    /// (left, right) -> merged id, for O(1) encode lookups.
    rank: HashMap<(u16, u16), u16>,
}

impl BpeTokenizer {
    /// Identity tokenizer: bytes only, no merges (vocab 260).
    pub fn byte_level() -> Self {
        BpeTokenizer { merges: Vec::new(), rank: HashMap::new() }
    }

    /// Train on an iterator of byte strings until the vocabulary reaches
    /// `vocab_size` (or no pair repeats).
    ///
    /// Incremental algorithm: pair counts are built once and *updated*
    /// at each merge site (±1 around the merged positions) instead of
    /// recounted per round, with a lazy max-heap selecting the next
    /// merge. Selection order (max count, smallest pair on ties) is
    /// identical to the naive recount trainer (`train_naive`, kept as
    /// the equivalence-test oracle). At vocab 8192 this is the
    /// difference between seconds and tens of minutes — see
    /// EXPERIMENTS.md §Perf.
    pub fn train<'a, I>(samples: I, vocab_size: usize) -> Result<Self>
    where
        I: IntoIterator<Item = &'a [u8]>,
    {
        ensure!(vocab_size >= MERGE_BASE as usize,
                "vocab_size must be >= {MERGE_BASE}");
        let n_merges = vocab_size - MERGE_BASE as usize;
        let mut seqs: Vec<Vec<u16>> = samples
            .into_iter()
            .map(|s| s.iter().map(|&b| BYTE_BASE + b as u16).collect())
            .collect();
        let mut merges = Vec::with_capacity(n_merges);
        let mut rank = HashMap::new();

        // initial counts
        let mut counts: HashMap<(u16, u16), i64> = HashMap::new();
        for seq in &seqs {
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
        }
        // lazy max-heap: (count, Reverse(pair)) — ties resolve to the
        // smallest pair, matching train_naive's max_by_key
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mut heap: BinaryHeap<(i64, Reverse<(u16, u16)>)> =
            counts.iter().map(|(&p, &c)| (c, Reverse(p))).collect();

        for m in 0..n_merges {
            // pop until a live entry surfaces
            let pair = loop {
                let Some(&(c, Reverse(p))) = heap.peek() else {
                    break None;
                };
                let live = counts.get(&p).copied().unwrap_or(0);
                if live != c {
                    heap.pop(); // stale
                    continue;
                }
                if c < 2 {
                    break None; // nothing repeats anymore
                }
                heap.pop();
                break Some(p);
            };
            let Some(pair) = pair else { break };

            let new_id = MERGE_BASE + m as u16;
            merges.push(pair);
            rank.insert(pair, new_id);
            counts.remove(&pair);

            // apply to every sequence, updating counts around each site
            let mut touched: Vec<(u16, u16)> = Vec::new();
            for seq in &mut seqs {
                Self::apply_merge_counting(seq, pair, new_id, &mut counts,
                                           &mut touched);
            }
            for p in touched.drain(..) {
                if let Some(&c) = counts.get(&p) {
                    if c > 0 {
                        heap.push((c, Reverse(p)));
                    }
                }
            }
        }
        Ok(BpeTokenizer { merges, rank })
    }

    /// `apply_merge` that also maintains the global pair-count map.
    fn apply_merge_counting(seq: &mut Vec<u16>, pair: (u16, u16),
                            new_id: u16,
                            counts: &mut HashMap<(u16, u16), i64>,
                            touched: &mut Vec<(u16, u16)>) {
        let mut bump = |counts: &mut HashMap<(u16, u16), i64>,
                        p: (u16, u16), d: i64,
                        touched: &mut Vec<(u16, u16)>| {
            let e = counts.entry(p).or_insert(0);
            *e += d;
            if *e <= 0 {
                counts.remove(&p);
            } else {
                // re-arm the heap on *any* surviving change: a pair
                // whose count only ever decreases would otherwise hide
                // behind its stale higher entries forever
                touched.push(p);
            }
        };
        let mut w = 0;
        let mut r = 0;
        while r < seq.len() {
            if r + 1 < seq.len() && seq[r] == pair.0 && seq[r + 1] == pair.1
            {
                // neighbors in the *evolving* sequence
                if w > 0 {
                    bump(counts, (seq[w - 1], pair.0), -1, touched);
                    bump(counts, (seq[w - 1], new_id), 1, touched);
                }
                if r + 2 < seq.len() {
                    bump(counts, (pair.1, seq[r + 2]), -1, touched);
                    bump(counts, (new_id, seq[r + 2]), 1, touched);
                }
                seq[w] = new_id;
                r += 2;
            } else {
                seq[w] = seq[r];
                r += 1;
            }
            w += 1;
        }
        seq.truncate(w);
    }

    /// Reference trainer: full recount every round. O(merges · corpus);
    /// pins `train`'s selection semantics in the equivalence test.
    pub fn train_naive<'a, I>(samples: I, vocab_size: usize) -> Result<Self>
    where
        I: IntoIterator<Item = &'a [u8]>,
    {
        ensure!(vocab_size >= MERGE_BASE as usize,
                "vocab_size must be >= {MERGE_BASE}");
        let n_merges = vocab_size - MERGE_BASE as usize;
        let mut seqs: Vec<Vec<u16>> = samples
            .into_iter()
            .map(|s| s.iter().map(|&b| BYTE_BASE + b as u16).collect())
            .collect();
        let mut merges = Vec::with_capacity(n_merges);
        let mut rank = HashMap::new();

        for m in 0..n_merges {
            let mut counts: HashMap<(u16, u16), u32> = HashMap::new();
            for seq in &seqs {
                for w in seq.windows(2) {
                    *counts.entry((w[0], w[1])).or_insert(0) += 1;
                }
            }
            let Some((&pair, &count)) = counts
                .iter()
                .max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if count < 2 {
                break;
            }
            let new_id = MERGE_BASE + m as u16;
            merges.push(pair);
            rank.insert(pair, new_id);
            for seq in &mut seqs {
                Self::apply_merge(seq, pair, new_id);
            }
        }
        Ok(BpeTokenizer { merges, rank })
    }

    fn apply_merge(seq: &mut Vec<u16>, pair: (u16, u16), new_id: u16) {
        let mut w = 0;
        let mut r = 0;
        while r < seq.len() {
            if r + 1 < seq.len() && seq[r] == pair.0 && seq[r + 1] == pair.1
            {
                seq[w] = new_id;
                r += 2;
            } else {
                seq[w] = seq[r];
                r += 1;
            }
            w += 1;
        }
        seq.truncate(w);
    }

    /// Total vocabulary size (specials + bytes + merges).
    pub fn vocab_size(&self) -> usize {
        MERGE_BASE as usize + self.merges.len()
    }

    /// Encode raw bytes to token ids (no specials added).
    ///
    /// Heap + doubly-linked-list BPE: every adjacent mergeable pair sits
    /// in a min-heap keyed by (merge rank, position); popping always
    /// applies the lowest-rank pair present, left-to-right on ties —
    /// exactly the semantics of the naive rescan (`encode_naive`, kept
    /// as the property-test oracle) at O(n log n) instead of
    /// O(n · merges). See EXPERIMENTS.md §Perf for the measured ~40×.
    pub fn encode(&self, bytes: &[u8]) -> Vec<u16> {
        let n = bytes.len();
        let mut ids: Vec<u16> =
            bytes.iter().map(|&b| BYTE_BASE + b as u16).collect();
        if self.merges.is_empty() || n < 2 {
            return ids;
        }
        // linked list over positions; usize::MAX = none
        const NONE: usize = usize::MAX;
        let mut next: Vec<usize> = (1..=n).collect();
        next[n - 1] = NONE;
        let mut prev: Vec<usize> = (0..n).map(|i| i.wrapping_sub(1))
            .collect(); // 0 -> MAX == NONE
        let mut alive = vec![true; n];

        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mut heap: BinaryHeap<Reverse<(u16, usize)>> =
            BinaryHeap::with_capacity(n);
        for i in 0..n - 1 {
            if let Some(&m) = self.rank.get(&(ids[i], ids[i + 1])) {
                heap.push(Reverse((m, i)));
            }
        }
        while let Some(Reverse((m, i))) = heap.pop() {
            if !alive[i] {
                continue;
            }
            let j = next[i];
            if j == NONE || !alive[j] {
                continue;
            }
            // stale-entry check: the pair must still merge to m
            if self.rank.get(&(ids[i], ids[j])) != Some(&m) {
                continue;
            }
            // merge j into i
            ids[i] = m;
            alive[j] = false;
            let k = next[j];
            next[i] = k;
            if k != NONE {
                prev[k] = i;
            }
            // new candidate pairs around the merged token
            let p = prev[i];
            if p != NONE && alive[p] {
                if let Some(&pm) = self.rank.get(&(ids[p], ids[i])) {
                    heap.push(Reverse((pm, p)));
                }
            }
            if k != NONE && alive[k] {
                if let Some(&nm) = self.rank.get(&(ids[i], ids[k])) {
                    heap.push(Reverse((nm, i)));
                }
            }
        }
        let mut out = Vec::with_capacity(n / 2);
        let mut i = 0;
        while i != NONE {
            out.push(ids[i]);
            i = next[i];
        }
        out
    }

    /// Reference encoder: rescan for the globally-lowest-rank pair and
    /// merge all its occurrences, repeat. O(n · merges); used by tests
    /// to pin `encode`'s semantics and by the §Perf before/after.
    pub fn encode_naive(&self, bytes: &[u8]) -> Vec<u16> {
        let mut seq: Vec<u16> =
            bytes.iter().map(|&b| BYTE_BASE + b as u16).collect();
        if self.merges.is_empty() || seq.len() < 2 {
            return seq;
        }
        loop {
            let mut best: Option<(u16, (u16, u16))> = None;
            for w in seq.windows(2) {
                if let Some(&id) = self.rank.get(&(w[0], w[1])) {
                    if best.map_or(true, |(bid, _)| id < bid) {
                        best = Some((id, (w[0], w[1])));
                    }
                }
            }
            let Some((id, pair)) = best else { break };
            Self::apply_merge(&mut seq, pair, id);
        }
        seq
    }

    /// Decode token ids back to bytes. Special tokens are skipped.
    pub fn decode(&self, ids: &[u16]) -> Vec<u8> {
        let mut out = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            self.expand(id, &mut out);
        }
        out
    }

    fn expand(&self, id: u16, out: &mut Vec<u8>) {
        if id < BYTE_BASE {
            // special token: no byte content
        } else if id < MERGE_BASE {
            out.push((id - BYTE_BASE) as u8);
        } else {
            let (l, r) = self.merges[(id - MERGE_BASE) as usize];
            self.expand(l, out);
            self.expand(r, out);
        }
    }

    /// Mean tokens-per-byte over a sample (compression diagnostic).
    pub fn tokens_per_byte(&self, sample: &[u8]) -> f64 {
        if sample.is_empty() {
            return 0.0;
        }
        self.encode(sample).len() as f64 / sample.len() as f64
    }

    // ---- persistence ------------------------------------------------------

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("format", json::s("txgain-bpe-v1")),
            ("vocab_size", json::num(self.vocab_size() as f64)),
            (
                "merges",
                Value::Arr(
                    self.merges
                        .iter()
                        .map(|(l, r)| {
                            Value::Arr(vec![json::num(*l as f64),
                                            json::num(*r as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        if v.req("format")?.as_str()? != "txgain-bpe-v1" {
            bail!("unknown tokenizer format");
        }
        let mut merges = Vec::new();
        let mut rank = HashMap::new();
        for (i, m) in v.req("merges")?.as_arr()?.iter().enumerate() {
            let m = m.as_arr()?;
            ensure!(m.len() == 2, "merge must be a pair");
            let pair = (m[0].as_u64()? as u16, m[1].as_u64()? as u16);
            merges.push(pair);
            rank.insert(pair, MERGE_BASE + i as u16);
        }
        Ok(BpeTokenizer { merges, rank })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&Value::parse(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn trained() -> BpeTokenizer {
        // repetitive corpus: merges must emerge
        let samples: Vec<Vec<u8>> = (0..50)
            .map(|i| {
                let mut v = b"\x55\x48\x89\xe5".repeat(8);
                v.push(i as u8);
                v.extend(b"\xc9\xc3");
                v
            })
            .collect();
        let refs: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
        BpeTokenizer::train(refs, 280).unwrap()
    }

    #[test]
    fn byte_level_roundtrip() {
        let t = BpeTokenizer::byte_level();
        let data: Vec<u8> = (0u16..256).map(|b| b as u8).collect();
        assert_eq!(t.decode(&t.encode(&data)), data);
        assert_eq!(t.vocab_size(), 260);
    }

    #[test]
    fn training_learns_frequent_pairs() {
        let t = trained();
        assert!(t.vocab_size() > MERGE_BASE as usize);
        // the prologue should compress well
        let tpb = t.tokens_per_byte(&b"\x55\x48\x89\xe5".repeat(8));
        assert!(tpb < 0.5, "tokens/byte={tpb}");
    }

    #[test]
    fn roundtrip_property_random_bytes() {
        // proptest-style: any byte string decodes back exactly
        let t = trained();
        let mut rng = Rng::new(77);
        for len in [0usize, 1, 2, 7, 63, 256, 1000] {
            for _ in 0..8 {
                let data: Vec<u8> =
                    (0..len).map(|_| rng.next_u64() as u8).collect();
                assert_eq!(t.decode(&t.encode(&data)), data, "len={len}");
            }
        }
    }

    #[test]
    fn roundtrip_property_corpus_functions() {
        let t = trained();
        let g = crate::data::CorpusGenerator::new(20, 6.0, 0.5, 5);
        for i in 0..20 {
            let f = g.generate(i);
            assert_eq!(t.decode(&t.encode(&f.bytes)), f.bytes);
        }
    }

    #[test]
    fn incremental_trainer_matches_naive_oracle() {
        // same corpus, same vocab: identical merge tables
        let g = crate::data::CorpusGenerator::new(30, 6.0, 0.6, 13);
        let fns: Vec<Vec<u8>> = (0..30).map(|i| g.generate(i).bytes)
            .collect();
        let refs = || fns.iter().map(|v| v.as_slice());
        let fast = BpeTokenizer::train(refs(), 500).unwrap();
        let slow = BpeTokenizer::train_naive(refs(), 500).unwrap();
        assert_eq!(fast.merges, slow.merges);
    }

    #[test]
    fn heap_encoder_matches_naive_oracle() {
        // proptest-style equivalence: the O(n log n) encoder must agree
        // with the rescan oracle on random and corpus-like inputs
        let t = trained();
        let mut rng = Rng::new(31);
        for _ in 0..40 {
            let len = 1 + rng.gen_range(600) as usize;
            let data: Vec<u8> = (0..len)
                .map(|_| {
                    // mix of repetitive (mergeable) and random bytes
                    if rng.next_f64() < 0.5 {
                        [0x55, 0x48, 0x89, 0xe5]
                            [rng.gen_range(4) as usize]
                    } else {
                        rng.next_u64() as u8
                    }
                })
                .collect();
            assert_eq!(t.encode(&data), t.encode_naive(&data));
        }
    }

    #[test]
    fn encode_is_deterministic() {
        let t = trained();
        let data = b"\x55\x48\x89\xe5\x48\x83\xec\x20".to_vec();
        assert_eq!(t.encode(&data), t.encode(&data));
    }

    #[test]
    fn json_roundtrip_preserves_encoding() {
        let t = trained();
        let t2 = BpeTokenizer::from_json(&t.to_json()).unwrap();
        let data = b"\x55\x48\x89\xe5\x55\x48\x89\xe5\xc9\xc3".to_vec();
        assert_eq!(t.encode(&data), t2.encode(&data));
        assert_eq!(t.vocab_size(), t2.vocab_size());
    }

    #[test]
    fn train_stops_when_nothing_repeats() {
        // all-distinct corpus: no merges learnable
        let s1: Vec<u8> = (0..=255u8).collect();
        let t = BpeTokenizer::train(vec![s1.as_slice()], 4096).unwrap();
        // each adjacent pair occurs once; count<2 stops training
        assert_eq!(t.vocab_size(), MERGE_BASE as usize);
    }

    #[test]
    fn rejects_too_small_vocab() {
        assert!(BpeTokenizer::train(vec![b"ab".as_slice()], 100).is_err());
    }
}
