//! Dataset staging (recommendation 2): price the two policies against
//! the cluster storage model, and actually stage shards to a local
//! directory for real-mode runs.
//!
//! The paper's finding: with the preprocessed dataset small enough
//! (rec 1), the one-time cost of copying it to every node's local SSD
//! beats having hundreds of nodes contend for Lustre every epoch.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::cluster::StorageModel;
use crate::config::{ClusterConfig, StagingPolicy};
use crate::Result;

/// Cost estimate for a staging policy over a whole run.
#[derive(Clone, Debug)]
pub struct StagingEstimate {
    pub policy: StagingPolicy,
    /// One-time stage-in wall time (0 for network-direct).
    pub stage_in_secs: f64,
    /// Per-epoch IO wall time for the rank-local fraction of the data.
    pub per_epoch_secs: f64,
}

impl StagingEstimate {
    pub fn total_secs(&self, epochs: usize) -> f64 {
        self.stage_in_secs + self.per_epoch_secs * epochs as f64
    }
}

/// Price a policy. Per-epoch traffic: full `dataset_bytes` per node —
/// the conservative upper bound (a flat random shuffle touches every
/// shard from every node). The PR-4 windowed plan assigns each rank a
/// *contiguous* stream segment, so a well-cached stream reads closer to
/// `dataset_bytes × gpus_per_node / world` per node; use
/// [`price_read`] with the trainer's measured `loader_bytes_read` to
/// price what a run actually pulled. Local-copy pays the same
/// amplification against its own SSD, where it is cheap and
/// uncontended.
pub fn estimate(cluster: &ClusterConfig, policy: StagingPolicy,
                dataset_bytes: u64) -> StagingEstimate {
    let storage = StorageModel::new(cluster);
    match policy {
        StagingPolicy::NetworkDirect => StagingEstimate {
            policy,
            stage_in_secs: 0.0,
            per_epoch_secs: storage
                .shared_read_time(cluster.nodes, dataset_bytes as f64),
        },
        StagingPolicy::LocalCopy => StagingEstimate {
            policy,
            stage_in_secs: storage
                .stage_in_time(cluster.nodes, dataset_bytes as f64),
            per_epoch_secs: storage.local_read_time(dataset_bytes as f64),
        },
    }
}

/// Price a *measured* per-node read volume under `policy` — the
/// cross-check between the trainer's `loader_bytes_read` column
/// (steps.csv / report.json, × ranks per node) and the storage model:
/// seconds the modeled array/SSD would need to serve what the stream
/// actually pulled. Shares [`estimate`]'s flow model, so the two are
/// directly comparable.
pub fn price_read(cluster: &ClusterConfig, policy: StagingPolicy,
                  bytes_per_node: u64) -> f64 {
    let storage = StorageModel::new(cluster);
    match policy {
        StagingPolicy::NetworkDirect => storage
            .shared_read_time(cluster.nodes, bytes_per_node as f64),
        StagingPolicy::LocalCopy => {
            storage.local_read_time(bytes_per_node as f64)
        }
    }
}

/// Epochs after which local-copy is cheaper than network-direct
/// (`None` if it never is).
pub fn break_even_epochs(cluster: &ClusterConfig, dataset_bytes: u64)
    -> Option<usize> {
    let net = estimate(cluster, StagingPolicy::NetworkDirect, dataset_bytes);
    let loc = estimate(cluster, StagingPolicy::LocalCopy, dataset_bytes);
    let saving = net.per_epoch_secs - loc.per_epoch_secs;
    if saving <= 0.0 {
        return None;
    }
    Some((loc.stage_in_secs / saving).ceil() as usize)
}

/// Really copy shard files into `local_dir` (the rank-local replica used
/// by real-mode training). Returns the staged paths.
pub fn stage_local(shards: &[PathBuf], local_dir: &Path)
    -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(local_dir)?;
    let mut staged = Vec::with_capacity(shards.len());
    for src in shards {
        let name = src.file_name()
            .context("shard path has no file name")?;
        let dst = local_dir.join(name);
        std::fs::copy(src, &dst)
            .with_context(|| format!("staging {} -> {}", src.display(),
                                     dst.display()))?;
        staged.push(dst);
    }
    Ok(staged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_copy_wins_at_scale() {
        // the paper's regime: 128 nodes, 25 GB preprocessed dataset
        let c = ClusterConfig::tx_gain(128);
        let ds = 25_000_000_000u64;
        let net = estimate(&c, StagingPolicy::NetworkDirect, ds);
        let loc = estimate(&c, StagingPolicy::LocalCopy, ds);
        // per-epoch: local SSD must be much faster than contended Lustre
        assert!(loc.per_epoch_secs < net.per_epoch_secs / 10.0,
                "loc={} net={}", loc.per_epoch_secs, net.per_epoch_secs);
        // and it amortizes within a handful of epochs
        let be = break_even_epochs(&c, ds).unwrap();
        assert!(be <= 5, "break-even at {be} epochs");
    }

    #[test]
    fn contention_penalty_grows_with_node_count() {
        // at N=1 the network:local gap is just client-cap vs SSD; at 128
        // nodes the saturated array makes it an order of magnitude
        let ds = 25_000_000_000u64;
        let gap = |nodes: usize| {
            let c = ClusterConfig::tx_gain(nodes);
            let net = estimate(&c, StagingPolicy::NetworkDirect, ds);
            let loc = estimate(&c, StagingPolicy::LocalCopy, ds);
            net.per_epoch_secs / loc.per_epoch_secs
        };
        let g1 = gap(1);
        let g128 = gap(128);
        assert!(g1 < 4.0, "g1={g1}");
        assert!(g128 > 8.0, "g128={g128}");
        assert!(g128 > 3.0 * g1);
    }

    #[test]
    fn price_read_is_consistent_with_estimate() {
        // pricing the model's own assumed volume must reproduce the
        // per-epoch estimate exactly, for both policies — so a measured
        // stream equal to the assumption closes the loop
        let c = ClusterConfig::tx_gain(64);
        let ds = 10_000_000_000u64;
        for policy in [StagingPolicy::NetworkDirect,
                       StagingPolicy::LocalCopy] {
            let est = estimate(&c, policy, ds);
            let priced = price_read(&c, policy, ds);
            assert!((priced - est.per_epoch_secs).abs()
                        < est.per_epoch_secs * 1e-9,
                    "{policy:?}: {priced} vs {}", est.per_epoch_secs);
        }
        // a cache-friendly stream (1/nodes of the data) prices cheaper
        let lean = price_read(&c, StagingPolicy::NetworkDirect, ds / 64);
        assert!(lean < price_read(&c, StagingPolicy::NetworkDirect, ds));
    }

    #[test]
    fn stage_local_copies_files() {
        let tmp = std::env::temp_dir()
            .join(format!("txgain-stage-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&tmp);
        let src_dir = tmp.join("shared");
        std::fs::create_dir_all(&src_dir).unwrap();
        let mut shards = Vec::new();
        for i in 0..3 {
            let p = src_dir.join(format!("shard-{i}.bin"));
            std::fs::write(&p, vec![i as u8; 128]).unwrap();
            shards.push(p);
        }
        let staged = stage_local(&shards, &tmp.join("local")).unwrap();
        assert_eq!(staged.len(), 3);
        for (i, p) in staged.iter().enumerate() {
            assert_eq!(std::fs::read(p).unwrap(), vec![i as u8; 128]);
        }
        std::fs::remove_dir_all(&tmp).unwrap();
    }
}
