//! Synthetic binary-code corpus generator.
//!
//! Substitute for the paper's proprietary 2 TB / 202M-sample dump of
//! compiled functions from nixpkgs (DESIGN.md §Substitutions). What the
//! experiments need from the data is its *storage profile*, not its
//! semantics:
//!   - samples are compiled function bodies with a long-tailed
//!     (log-normal) size distribution,
//!   - raw storage is bulky and compresses poorly (instruction soup with
//!     high-entropy immediates, stored as JSONL with hex-encoded bytes
//!     plus build metadata — the shape of a typical extraction pipeline),
//!   - generation is deterministic per (seed, index), so a multi-GB
//!     corpus never needs to exist on disk to be measured.
//!
//! The generator emits x86-64-flavoured byte streams: prologue, a body
//! sampled from an opcode table with random immediates/displacements,
//! epilogue. This is NOT a valid-instruction assembler — it is a source
//! of bytes whose n-gram statistics resemble compiled code well enough
//! for BPE and compression-ratio experiments.

use crate::util::Rng;

/// One raw "compiled function" plus its extraction metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct RawFunction {
    pub project: String,
    pub name: String,
    pub opt_level: &'static str,
    pub bytes: Vec<u8>,
}

/// Weighted opcode skeletons: (leading bytes, immediate bytes to append).
/// Rough frequencies of common x86-64 instruction families.
const OPS: &[(&[u8], usize, u32)] = &[
    (&[0x48, 0x89], 1, 18), // mov r/m64, r64 + modrm
    (&[0x48, 0x8b], 1, 18), // mov r64, r/m64 + modrm
    (&[0x89], 1, 10),       // mov r/m32, r32
    (&[0x48, 0x83], 2, 8),  // arith r/m64, imm8
    (&[0x48, 0x81], 5, 2),  // arith r/m64, imm32
    (&[0xe8], 4, 7),        // call rel32
    (&[0xe9], 4, 3),        // jmp rel32
    (&[0x74], 1, 6),        // je rel8
    (&[0x75], 1, 6),        // jne rel8
    (&[0x0f, 0x84], 4, 3),  // je rel32
    (&[0x8d], 1, 4),        // lea
    (&[0x48, 0x8d], 1, 6),  // lea r64
    (&[0x85], 1, 5),        // test
    (&[0x31], 1, 4),        // xor
    (&[0x50], 0, 3),        // push rax
    (&[0x58], 0, 3),        // pop rax
    (&[0xc7], 5, 3),        // mov r/m32, imm32
    (&[0x66, 0x0f, 0x1f], 2, 1), // nop padding
    (&[0xf3, 0x0f, 0x10], 1, 2), // movss
    (&[0x48, 0x01], 1, 4),  // add r/m64, r64
    (&[0x48, 0x29], 1, 3),  // sub r/m64, r64
    (&[0x48, 0x39], 1, 4),  // cmp r/m64, r64
];

const PROJECTS: &[&str] = &[
    "coreutils", "openssl", "zlib", "sqlite", "curl", "ffmpeg", "binutils",
    "glibc", "busybox", "libpng", "systemd", "nginx", "git", "perl",
    "python3", "gcc-libs", "ncurses", "readline", "pcre2", "xz",
];

/// Deterministic corpus: `generate(i)` is a pure function of
/// `(seed, i, size model)`.
pub struct CorpusGenerator {
    seed_rng: Rng,
    pub samples: usize,
    mu: f64,
    sigma: f64,
}

impl CorpusGenerator {
    pub fn new(samples: usize, fn_size_mu: f64, fn_size_sigma: f64,
               seed: u64) -> Self {
        CorpusGenerator {
            seed_rng: Rng::new(seed).derive("corpus"),
            samples,
            mu: fn_size_mu,
            sigma: fn_size_sigma,
        }
    }

    pub fn from_config(cfg: &crate::config::DataConfig, seed: u64) -> Self {
        Self::new(cfg.corpus_samples, cfg.fn_size_mu, cfg.fn_size_sigma,
                  seed)
    }

    /// Generate function `idx` (0-based). Deterministic.
    pub fn generate(&self, idx: usize) -> RawFunction {
        assert!(idx < self.samples, "index {idx} out of corpus");
        let mut rng = self.seed_rng.derive(&format!("fn:{idx}"));
        let target = rng.lognormal(self.mu, self.sigma).clamp(32.0, 1e6)
            as usize;

        let mut bytes = Vec::with_capacity(target + 16);
        // prologue: push rbp; mov rbp, rsp; sub rsp, imm8
        bytes.extend_from_slice(&[0x55, 0x48, 0x89, 0xe5, 0x48, 0x83, 0xec]);
        bytes.push((rng.gen_range(32) * 8) as u8);
        while bytes.len() < target.saturating_sub(2) {
            let total: u32 = OPS.iter().map(|o| o.2).sum();
            let mut pick = rng.gen_range(total as u64) as u32;
            let mut chosen = &OPS[0];
            for op in OPS {
                if pick < op.2 {
                    chosen = op;
                    break;
                }
                pick -= op.2;
            }
            bytes.extend_from_slice(chosen.0);
            for _ in 0..chosen.1 {
                bytes.push(rng.next_u64() as u8); // high-entropy immediates
            }
        }
        // epilogue: leave; ret
        bytes.extend_from_slice(&[0xc9, 0xc3]);

        let project = PROJECTS[rng.gen_range(PROJECTS.len() as u64) as usize];
        RawFunction {
            project: project.to_string(),
            name: format!("_Z{}fn_{:08x}v", project.len(),
                          rng.next_u64() as u32),
            opt_level: ["O0", "O1", "O2", "O3", "Os"]
                [rng.gen_range(5) as usize],
            bytes,
        }
    }

    /// The raw on-disk representation: one JSONL record with hex bytes +
    /// metadata, mimicking the extraction-pipeline format whose bulk the
    /// paper's recommendation 1 eliminates.
    pub fn raw_json_line(f: &RawFunction) -> String {
        let mut hex = String::with_capacity(f.bytes.len() * 2);
        for b in &f.bytes {
            hex.push_str(&format!("{b:02x}"));
        }
        format!(
            "{{\"project\":\"{}\",\"function\":\"{}\",\"opt\":\"{}\",\
             \"size\":{},\"bytes\":\"{}\"}}\n",
            f.project, f.name, f.opt_level, f.bytes.len(), hex
        )
    }

    /// Exact raw-format size of sample `idx` without materializing it
    /// twice (used by the rec-1 accounting).
    pub fn raw_line_bytes(&self, idx: usize) -> u64 {
        let f = self.generate(idx);
        Self::raw_json_line(&f).len() as u64
    }

    /// Mean raw bytes/sample extrapolated from a deterministic sample of
    /// the corpus (the full corpus can be paper-scale).
    pub fn estimated_raw_bytes(&self, probe: usize) -> u64 {
        let probe = probe.min(self.samples).max(1);
        let total: u64 = (0..probe)
            .map(|i| self.raw_line_bytes(i * self.samples / probe))
            .sum();
        total / probe as u64 * self.samples as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn generator() -> CorpusGenerator {
        CorpusGenerator::new(1000, 6.5, 0.8, 42)
    }

    #[test]
    fn deterministic_per_index() {
        let g1 = generator();
        let g2 = generator();
        for i in [0, 1, 500, 999] {
            assert_eq!(g1.generate(i), g2.generate(i));
        }
    }

    #[test]
    fn different_indices_differ() {
        let g = generator();
        assert_ne!(g.generate(0).bytes, g.generate(1).bytes);
    }

    #[test]
    fn functions_have_prologue_and_ret() {
        let g = generator();
        for i in 0..20 {
            let f = g.generate(i);
            assert_eq!(&f.bytes[..4], &[0x55, 0x48, 0x89, 0xe5]);
            assert_eq!(f.bytes[f.bytes.len() - 1], 0xc3);
            assert!(f.bytes.len() >= 32);
        }
    }

    #[test]
    fn sizes_follow_lognormal_roughly() {
        let g = CorpusGenerator::new(2000, 6.5, 0.8, 7);
        let sizes: Vec<f64> =
            (0..500).map(|i| g.generate(i).bytes.len() as f64).collect();
        let mean_log =
            sizes.iter().map(|s| s.ln()).sum::<f64>() / sizes.len() as f64;
        // prologue/epilogue padding shifts the mean slightly upward
        assert!((mean_log - 6.5).abs() < 0.35, "mean_log={mean_log}");
    }

    #[test]
    fn raw_json_is_parseable_and_bulky() {
        let g = generator();
        let f = g.generate(3);
        let line = CorpusGenerator::raw_json_line(&f);
        let v = crate::util::json::Value::parse(line.trim()).unwrap();
        assert_eq!(v.req("size").unwrap().as_usize().unwrap(),
                   f.bytes.len());
        // hex + metadata: at least 2x the function body
        assert!(line.len() as f64 > 2.0 * f.bytes.len() as f64);
    }

    #[test]
    fn estimated_raw_bytes_close_to_exact_on_small_corpus() {
        let g = CorpusGenerator::new(200, 6.0, 0.5, 3);
        let exact: u64 = (0..200).map(|i| g.raw_line_bytes(i)).sum();
        let est = g.estimated_raw_bytes(200);
        let rel = (est as f64 - exact as f64).abs() / exact as f64;
        assert!(rel < 0.01, "rel={rel}");
    }

    #[test]
    fn high_entropy_bytes() {
        // immediates should make the body hard to compress: check byte
        // histogram is not concentrated
        let g = CorpusGenerator::new(10, 9.0, 0.3, 9);
        let f = g.generate(0);
        let mut hist = [0usize; 256];
        for b in &f.bytes {
            hist[*b as usize] += 1;
        }
        let distinct = hist.iter().filter(|&&c| c > 0).count();
        assert!(distinct > 128, "distinct={distinct}");
    }
}
