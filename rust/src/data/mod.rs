//! The data pipeline (paper §II-A): synthetic binary-code corpus →
//! byte-level BPE tokenizer → preprocessed packed shards → staging →
//! parallel loader → masked batches.
//!
//! The paper's first two recommendations live here:
//! 1. *Preprocess and tokenize ahead of training, storing only tokenized
//!    inputs and attention masks* — [`preprocess`] turns the raw
//!    JSONL+hex corpus (the storage profile of the paper's 2 TB nixpkgs
//!    function dump) into packed u16 shards, a ~99 % reduction.
//! 2. *Duplicate the dataset across nodes before training* —
//!    [`staging`] plans and executes the local-SSD copy and prices both
//!    policies against the cluster storage model.
//!
//! Recommendation 3 (parallel data loading) is [`loader`]. Since PR 4
//! the loaders are *memory-bounded*: [`index`] maps global sample ids
//! to shard offsets header-only and serves reads through a
//! byte-budgeted LRU block cache, and [`shard`]'s windowed two-level
//! shuffle replaces the O(corpus) per-rank epoch materialization with a
//! lazy cursor — resident bytes are O(`data.cache_mb` +
//! `data.shuffle_window`), never O(corpus).

pub mod corpus;
pub mod index;
pub mod loader;
pub mod masking;
pub mod preprocess;
pub mod records;
pub mod shard;
pub mod staging;
pub mod tokenizer;

pub use corpus::{CorpusGenerator, RawFunction};
pub use index::{BlockCache, DatasetIndex, IoStats};
pub use loader::{HostBatch, LoaderPool, LoaderStats};
pub use masking::Masker;
pub use preprocess::{preprocess_corpus, PreprocessStats};
pub use records::{Sample, ShardReader, ShardWriter};
pub use shard::{EpochPlan, RankCursor, WindowedPlan};
pub use tokenizer::BpeTokenizer;

/// Special token ids shared by the whole pipeline (and the L2 model:
/// vocab slots 0..4 are reserved by construction).
pub mod special {
    pub const PAD: u16 = 0;
    pub const CLS: u16 = 1;
    pub const SEP: u16 = 2;
    pub const MASK: u16 = 3;
    /// First id that encodes a raw byte (byte b => id BYTE_BASE + b).
    pub const BYTE_BASE: u16 = 4;
    /// First id available for learned BPE merges.
    pub const MERGE_BASE: u16 = BYTE_BASE + 256;
}
