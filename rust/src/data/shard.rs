//! Epoch planning: deterministic shuffling and exactly-balanced
//! assignment of samples to data-parallel ranks (the DistributedSampler
//! role). Invariants (property-tested):
//!   - every rank gets the same number of samples (padding by wraparound,
//!     like PyTorch's DistributedSampler),
//!   - the un-padded union covers every sample exactly once,
//!   - plans are deterministic in (seed, epoch) and differ across epochs.

use crate::util::Rng;

/// The assignment of global sample indices to ranks for one epoch.
#[derive(Clone, Debug)]
pub struct EpochPlan {
    pub epoch: u64,
    pub per_rank: Vec<Vec<u32>>,
    /// Indices that appear twice because of wraparound padding.
    pub padded: usize,
}

impl EpochPlan {
    /// Build the plan for `epoch` over `n_samples` across `world` ranks.
    pub fn build(n_samples: usize, world: usize, epoch: u64, seed: u64)
        -> EpochPlan {
        assert!(world > 0 && n_samples > 0);
        let mut order: Vec<u32> = (0..n_samples as u32).collect();
        let mut rng =
            Rng::new(seed).derive(&format!("epoch-shuffle:{epoch}"));
        rng.shuffle(&mut order);
        // pad to a multiple of world by wrapping the shuffled order
        let per = n_samples.div_ceil(world);
        let padded = per * world - n_samples;
        for i in 0..padded {
            let v = order[i % n_samples];
            order.push(v);
        }
        let per_rank = (0..world)
            .map(|r| order[r * per..(r + 1) * per].to_vec())
            .collect();
        EpochPlan { epoch, per_rank, padded }
    }

    pub fn samples_per_rank(&self) -> usize {
        self.per_rank[0].len()
    }

    /// Number of optimizer steps this plan supports at `batch` per rank.
    pub fn steps(&self, batch: usize) -> usize {
        self.samples_per_rank() / batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn ranks_are_balanced() {
        let p = EpochPlan::build(1000, 7, 0, 1);
        let per = p.samples_per_rank();
        assert!(p.per_rank.iter().all(|r| r.len() == per));
        assert_eq!(per * 7 - 1000, p.padded);
    }

    #[test]
    fn covers_every_sample_exactly_once_modulo_padding() {
        // proptest-style sweep over (n, world, epoch)
        let mut rng = crate::util::Rng::new(99);
        for _ in 0..25 {
            let n = 1 + rng.gen_range(5000) as usize;
            let world = 1 + rng.gen_range(16) as usize;
            let epoch = rng.gen_range(10);
            let p = EpochPlan::build(n, world, epoch, 42);
            let mut seen: Vec<u32> =
                p.per_rank.iter().flatten().copied().collect();
            assert_eq!(seen.len(), n + p.padded);
            seen.sort();
            let distinct: HashSet<u32> = seen.iter().copied().collect();
            assert_eq!(distinct.len(), n, "n={n} world={world}");
            assert_eq!(*seen.last().unwrap(), n as u32 - 1);
        }
    }

    #[test]
    fn deterministic_and_epoch_varying() {
        let a = EpochPlan::build(500, 4, 3, 7);
        let b = EpochPlan::build(500, 4, 3, 7);
        assert_eq!(a.per_rank, b.per_rank);
        let c = EpochPlan::build(500, 4, 4, 7);
        assert_ne!(a.per_rank, c.per_rank);
    }

    #[test]
    fn steps_counts_full_batches() {
        let p = EpochPlan::build(100, 2, 0, 1); // 50 per rank
        assert_eq!(p.steps(8), 6);
        assert_eq!(p.steps(64), 0);
    }

    #[test]
    fn single_rank_gets_everything() {
        let p = EpochPlan::build(64, 1, 0, 5);
        assert_eq!(p.per_rank[0].len(), 64);
        assert_eq!(p.padded, 0);
    }
}
