//! Epoch planning: deterministic shuffling and exactly-balanced
//! assignment of samples to data-parallel ranks (the DistributedSampler
//! role). Two planners share the invariants (property-tested):
//!   - every rank gets the same number of samples (padding by wraparound,
//!     like PyTorch's DistributedSampler),
//!   - the un-padded union covers every sample exactly once,
//!   - plans are deterministic in (seed, epoch) and differ across epochs.
//!
//! [`EpochPlan`] is the original O(corpus) materialized plan — still the
//! simplest thing for small in-memory datasets and the reference the
//! equivalence tests compare against. [`WindowedPlan`] is the streaming
//! replacement: a *two-level* shuffle (deterministic shard-order shuffle
//! + per-window sample shuffle) evaluated lazily through a
//! [`RankCursor`], so a rank's epoch order costs O(`shuffle_window`)
//! memory instead of O(corpus) — and any position can be computed
//! directly, which is what makes mid-epoch resume a seek instead of a
//! replay. Bit-deterministic in (seed, epoch, rank) at any worker count.

use std::sync::Arc;

use anyhow::ensure;

use crate::util::Rng;
use crate::Result;

/// The assignment of global sample indices to ranks for one epoch,
/// fully materialized (O(corpus) — the in-memory reference path).
#[derive(Clone, Debug)]
pub struct EpochPlan {
    pub epoch: u64,
    pub per_rank: Vec<Vec<u32>>,
    /// Indices that appear twice because of wraparound padding.
    pub padded: usize,
}

impl EpochPlan {
    /// Build the plan for `epoch` over `n_samples` across `world` ranks.
    pub fn build(n_samples: usize, world: usize, epoch: u64, seed: u64)
        -> Result<EpochPlan> {
        ensure!(world > 0, "epoch plan needs at least one rank");
        ensure!(n_samples > 0, "epoch plan over an empty dataset");
        let mut order: Vec<u32> = (0..n_samples as u32).collect();
        let mut rng =
            Rng::new(seed).derive(&format!("epoch-shuffle:{epoch}"));
        rng.shuffle(&mut order);
        // pad to a multiple of world by wrapping the shuffled order
        let per = n_samples.div_ceil(world);
        let padded = per * world - n_samples;
        for i in 0..padded {
            let v = order[i % n_samples];
            order.push(v);
        }
        let per_rank = (0..world)
            .map(|r| order[r * per..(r + 1) * per].to_vec())
            .collect();
        Ok(EpochPlan { epoch, per_rank, padded })
    }

    pub fn samples_per_rank(&self) -> usize {
        self.per_rank[0].len()
    }

    /// Number of optimizer steps this plan supports at `batch` per rank.
    pub fn steps(&self, batch: usize) -> usize {
        self.samples_per_rank() / batch
    }
}

/// Streaming two-level shuffle plan for one epoch.
///
/// Level 1 shuffles the *shard order* (so ranks walk shards in a
/// different order every epoch and IO spreads across the array); level
/// 2 shuffles samples inside consecutive `window`-sized spans of the
/// resulting stream. Each rank owns a contiguous `per`-sized segment of
/// the stream (positions `[rank·per, (rank+1)·per)`, wrapping to the
/// stream's start for the padded tail) — contiguous segments keep a
/// rank's reads local to ~1/world of the shards, the IO-balance shape
/// recommendation 2 wants.
///
/// Nothing O(corpus) is ever allocated: `sample_at` computes any stream
/// position from (seed, epoch) plus one resident window permutation.
#[derive(Debug)]
pub struct WindowedPlan {
    pub epoch: u64,
    seed: u64,
    world: usize,
    /// Real (un-padded) samples in the stream.
    n: u64,
    window: usize,
    /// Samples per rank after wraparound padding.
    per: usize,
    /// Shuffled shard order (level 1).
    order: Vec<u32>,
    /// Cumulative sample counts in *shuffled* order, len shards+1.
    starts: Vec<u64>,
    /// Global-id base of each shard in *original* order.
    bases: Vec<u64>,
}

impl WindowedPlan {
    /// Build the plan for `epoch` over shards with the given per-shard
    /// sample `counts`, across `world` ranks, shuffling inside
    /// `window`-sample spans. For a single in-memory "shard" pass
    /// `&[n]` — level 1 degenerates and only the windowed sample
    /// shuffle remains.
    pub fn build(counts: &[u64], world: usize, epoch: u64, seed: u64,
                 window: usize) -> Result<WindowedPlan> {
        ensure!(world > 0, "windowed plan needs at least one rank");
        ensure!(window > 0, "shuffle_window must be at least 1");
        ensure!(!counts.is_empty(), "windowed plan over zero shards");
        let n: u64 = counts.iter().sum();
        ensure!(n > 0, "windowed plan over an empty dataset");
        ensure!(n <= u32::MAX as u64,
                "dataset of {n} samples exceeds the u32 id space");

        let mut order: Vec<u32> = (0..counts.len() as u32).collect();
        let mut rng =
            Rng::new(seed).derive_mix("shard-shuffle", &[epoch]);
        rng.shuffle(&mut order);

        let mut starts = Vec::with_capacity(counts.len() + 1);
        let mut acc = 0u64;
        starts.push(0);
        for &s in &order {
            acc += counts[s as usize];
            starts.push(acc);
        }
        let mut bases = Vec::with_capacity(counts.len());
        let mut base = 0u64;
        for &c in counts {
            bases.push(base);
            base += c;
        }
        let per = (n as usize).div_ceil(world);
        Ok(WindowedPlan { epoch, seed, world, n, window, per, order,
                          starts, bases })
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn samples_per_rank(&self) -> usize {
        self.per
    }

    /// Indices that appear twice because of wraparound padding.
    pub fn padded(&self) -> usize {
        self.per * self.world - self.n as usize
    }

    /// Number of optimizer steps this plan supports at `batch` per rank.
    pub fn steps(&self, batch: usize) -> usize {
        self.per / batch
    }

    /// Samples carried *into* this epoch from the previous epoch's
    /// undelivered tail under remainder roll-in (data-plane open item
    /// (c)): each epoch leaves `(carry_in + per) % batch` samples that
    /// did not fill a batch, and they lead the next epoch's stream
    /// instead of being dropped. Closed form — `(epoch · per) % batch`
    /// — so any epoch's carry is computable directly from (seed-free)
    /// geometry: bit-deterministic in (epoch, per, batch), which is
    /// what keeps mid-epoch resume a pure index computation.
    pub fn carry_in(&self, batch: usize) -> usize {
        debug_assert!(batch > 0);
        ((self.epoch as u128 * self.per as u128) % batch as u128)
            as usize
    }

    /// Steps this epoch delivers under remainder roll-in: the carried
    /// tail plus this epoch's own samples, cut into full batches.
    /// Always ≥ [`WindowedPlan::steps`]; the new remainder
    /// `(carry_in + per) % batch` becomes the next epoch's carry.
    pub fn steps_with_carry(&self, batch: usize) -> usize {
        (self.carry_in(batch) + self.per) / batch
    }

    /// The level-2 shuffle window size, in samples — the loader's
    /// prefetcher sizes its lookahead to stay about one window ahead.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Number of level-2 windows covering the stream.
    pub fn n_windows(&self) -> usize {
        (self.n as usize).div_ceil(self.window)
    }

    /// (start, len) of window `w` in stream coordinates.
    fn window_span(&self, w: usize) -> (u64, usize) {
        let start = (w * self.window) as u64;
        let len = (self.n - start).min(self.window as u64) as usize;
        (start, len)
    }

    /// The level-2 permutation of window `w` — deterministic in
    /// (seed, epoch, w), O(window) to generate.
    fn window_perm(&self, w: usize) -> Vec<u32> {
        let (_, len) = self.window_span(w);
        let mut perm: Vec<u32> = (0..len as u32).collect();
        let mut rng = Rng::new(self.seed)
            .derive_mix("window-shuffle", &[self.epoch, w as u64]);
        rng.shuffle(&mut perm);
        perm
    }

    /// Map a post-shuffle stream slot to the global sample id, through
    /// the shuffled shard concatenation (level 1).
    fn slot_to_id(&self, slot: u64) -> u32 {
        debug_assert!(slot < self.n);
        let j = self.starts.partition_point(|&s| s <= slot) - 1;
        (self.bases[self.order[j] as usize] + (slot - self.starts[j]))
            as u32
    }

    /// Global sample id at stream position `pos` (after both shuffle
    /// levels), given the resident permutation for `pos`'s window.
    /// Internal: use [`RankCursor`], which manages the permutation.
    fn sample_at(&self, pos: u64, perm: &[u32]) -> u32 {
        let w = (pos / self.window as u64) as usize;
        let (wstart, _) = self.window_span(w);
        let slot = wstart + perm[(pos - wstart) as usize] as u64;
        self.slot_to_id(slot)
    }

    /// O(corpus/world) materialization of one rank's order — the
    /// reference the streaming path is property-tested against, and the
    /// bridge for the in-memory `LoaderPool::spawn`.
    pub fn materialize_rank(self: &Arc<Self>, rank: usize) -> Vec<u32> {
        let mut cur = RankCursor::new(self.clone(), rank);
        (0..self.per).map(|k| cur.id_at(k)).collect()
    }
}

/// Lazy per-rank view of a [`WindowedPlan`]: computes sample ids on
/// demand, keeping exactly one window permutation resident (4 B ×
/// `shuffle_window`). Each loader worker owns its own cursor; cursors
/// are cheap and independent, so determinism never depends on worker
/// count or interleaving.
pub struct RankCursor {
    plan: Arc<WindowedPlan>,
    rank: usize,
    cached_window: Option<usize>,
    perm: Vec<u32>,
}

impl RankCursor {
    pub fn new(plan: Arc<WindowedPlan>, rank: usize) -> RankCursor {
        debug_assert!(rank < plan.world);
        RankCursor { plan, rank, cached_window: None, perm: Vec::new() }
    }

    /// Stream position of this rank's `k`-th sample (wraparound-padded
    /// like [`EpochPlan`]: padded tail positions re-use the stream's
    /// first positions).
    fn position(&self, k: usize) -> u64 {
        let g = (self.rank * self.plan.per + k) as u64;
        if g < self.plan.n { g } else { (g - self.plan.n) % self.plan.n }
    }

    /// Global sample id of this rank's `k`-th sample this epoch.
    pub fn id_at(&mut self, k: usize) -> u32 {
        debug_assert!(k < self.plan.per);
        let pos = self.position(k);
        let w = (pos / self.plan.window as u64) as usize;
        if self.cached_window != Some(w) {
            self.perm = self.plan.window_perm(w);
            self.cached_window = Some(w);
        }
        self.plan.sample_at(pos, &self.perm)
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn ranks_are_balanced() {
        let p = EpochPlan::build(1000, 7, 0, 1).unwrap();
        let per = p.samples_per_rank();
        assert!(p.per_rank.iter().all(|r| r.len() == per));
        assert_eq!(per * 7 - 1000, p.padded);
    }

    #[test]
    fn covers_every_sample_exactly_once_modulo_padding() {
        // proptest-style sweep over (n, world, epoch)
        let mut rng = crate::util::Rng::new(99);
        for _ in 0..25 {
            let n = 1 + rng.gen_range(5000) as usize;
            let world = 1 + rng.gen_range(16) as usize;
            let epoch = rng.gen_range(10);
            let p = EpochPlan::build(n, world, epoch, 42).unwrap();
            let mut seen: Vec<u32> =
                p.per_rank.iter().flatten().copied().collect();
            assert_eq!(seen.len(), n + p.padded);
            seen.sort();
            let distinct: HashSet<u32> = seen.iter().copied().collect();
            assert_eq!(distinct.len(), n, "n={n} world={world}");
            assert_eq!(*seen.last().unwrap(), n as u32 - 1);
        }
    }

    #[test]
    fn deterministic_and_epoch_varying() {
        let a = EpochPlan::build(500, 4, 3, 7).unwrap();
        let b = EpochPlan::build(500, 4, 3, 7).unwrap();
        assert_eq!(a.per_rank, b.per_rank);
        let c = EpochPlan::build(500, 4, 4, 7).unwrap();
        assert_ne!(a.per_rank, c.per_rank);
    }

    #[test]
    fn steps_counts_full_batches() {
        let p = EpochPlan::build(100, 2, 0, 1).unwrap(); // 50 per rank
        assert_eq!(p.steps(8), 6);
        assert_eq!(p.steps(64), 0);
    }

    #[test]
    fn single_rank_gets_everything() {
        let p = EpochPlan::build(64, 1, 0, 5).unwrap();
        assert_eq!(p.per_rank[0].len(), 64);
        assert_eq!(p.padded, 0);
    }

    #[test]
    fn degenerate_inputs_error_instead_of_asserting() {
        assert!(EpochPlan::build(0, 2, 0, 1).is_err());
        assert!(EpochPlan::build(10, 0, 0, 1).is_err());
        assert!(WindowedPlan::build(&[0], 2, 0, 1, 4).is_err());
        assert!(WindowedPlan::build(&[10], 0, 0, 1, 4).is_err());
        assert!(WindowedPlan::build(&[10], 2, 0, 1, 0).is_err());
        assert!(WindowedPlan::build(&[], 2, 0, 1, 4).is_err());
    }

    fn windowed(counts: &[u64], world: usize, epoch: u64, window: usize)
        -> Arc<WindowedPlan> {
        Arc::new(
            WindowedPlan::build(counts, world, epoch, 42, window)
                .unwrap())
    }

    #[test]
    fn windowed_covers_every_sample_exactly_once_modulo_padding() {
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..25 {
            // random shard layout, world, window, epoch
            let shards = 1 + rng.gen_range(6) as usize;
            let counts: Vec<u64> =
                (0..shards).map(|_| 1 + rng.gen_range(200)).collect();
            let n: u64 = counts.iter().sum();
            let world = 1 + rng.gen_range(8) as usize;
            let window = 1 + rng.gen_range(64) as usize;
            let epoch = rng.gen_range(5);
            let p = windowed(&counts, world, epoch, window);

            let mut seen: Vec<u32> = (0..world)
                .flat_map(|r| p.materialize_rank(r))
                .collect();
            assert_eq!(seen.len(), n as usize + p.padded());
            seen.sort();
            let distinct: HashSet<u32> = seen.iter().copied().collect();
            assert_eq!(distinct.len(), n as usize,
                       "counts={counts:?} world={world} window={window}");
            assert_eq!(*seen.last().unwrap() as u64, n - 1);
        }
    }

    #[test]
    fn windowed_is_deterministic_and_epoch_varying() {
        let counts = [100u64, 37, 63];
        let a = windowed(&counts, 4, 3, 16);
        let b = windowed(&counts, 4, 3, 16);
        let c = windowed(&counts, 4, 4, 16);
        for r in 0..4 {
            assert_eq!(a.materialize_rank(r), b.materialize_rank(r));
        }
        assert_ne!(a.materialize_rank(0), c.materialize_rank(0));
    }

    #[test]
    fn cursor_matches_materialized_order_at_random_access() {
        // id_at is position-addressable: jumping around (the resume
        // seek) must agree with the sequential materialization
        let p = windowed(&[80, 45], 3, 2, 32);
        for rank in 0..3 {
            let full = p.materialize_rank(rank);
            let mut cur = RankCursor::new(p.clone(), rank);
            for &k in &[41usize, 0, full.len() - 1, 7, 41, 23] {
                assert_eq!(cur.id_at(k), full[k], "rank {rank} k {k}");
            }
            // a batch worth of consecutive positions (what the loader
            // walks per step) agrees with the materialized order
            let ids: Vec<u32> =
                (10..15).map(|k| cur.id_at(k)).collect();
            assert_eq!(ids, &full[10..15]);
        }
    }

    #[test]
    fn window_one_degenerates_to_shard_order_only() {
        // window 1: level 2 is the identity, so the stream is just the
        // shuffled shard concatenation — ids within one shard stay
        // consecutive
        let p = windowed(&[50, 50], 1, 0, 1);
        let order = p.materialize_rank(0);
        let mut breaks = 0;
        for w in order.windows(2) {
            if w[1] != w[0] + 1 {
                breaks += 1;
            }
        }
        assert!(breaks <= 1, "expected at most one shard boundary jump");
    }

    #[test]
    fn whole_corpus_window_shuffles_globally() {
        // window >= n: one permutation spanning the stream
        let p = windowed(&[64], 1, 0, 1 << 20);
        let order = p.materialize_rank(0);
        assert_ne!(order, (0..64).collect::<Vec<u32>>());
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(sorted, (0..64).collect::<Vec<u32>>());
    }

    #[test]
    fn windowed_steps_counts_full_batches() {
        let p = windowed(&[100], 2, 0, 16); // 50 per rank
        assert_eq!(p.steps(8), 6);
        assert_eq!(p.steps(64), 0);
        assert_eq!(p.samples_per_rank(), 50);
    }

    #[test]
    fn carry_recurrence_matches_the_closed_form() {
        // carry_in(e+1) == (carry_in(e) + per) % batch — the closed
        // form IS the recurrence, so each epoch's leftover really is
        // what the next epoch starts with, for any geometry
        for (counts, world, batch) in
            [(vec![100u64], 2usize, 8usize), (vec![37, 63], 3, 7),
             (vec![50], 1, 50), (vec![11, 13], 4, 5)]
        {
            let mut prev_carry = 0usize;
            for epoch in 0..12u64 {
                let p = windowed(&counts, world, epoch, 16);
                let carry = p.carry_in(batch);
                assert_eq!(
                    carry, prev_carry,
                    "counts={counts:?} world={world} batch={batch} \
                     epoch={epoch}");
                // delivered + leftover accounts for every sample
                let per = p.samples_per_rank();
                assert_eq!(p.steps_with_carry(batch) * batch
                               + (carry + per) % batch,
                           carry + per);
                prev_carry = (carry + per) % batch;
            }
        }
        // epoch 0 never carries; even batches never carry
        let p = windowed(&[100], 2, 0, 16);
        assert_eq!(p.carry_in(8), 0);
        let p = windowed(&[96], 2, 5, 16); // 48/rank, batch 8 divides
        assert_eq!(p.carry_in(8), 0);
        assert_eq!(p.steps_with_carry(8), p.steps(8));
    }
}
