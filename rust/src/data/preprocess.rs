//! Ahead-of-time preprocessing (recommendation 1): raw corpus →
//! tokenizer training → packed shards, with the size accounting that
//! reproduces the paper's "2 TB → 25 GB (−99 %)" observation.

use std::path::{Path, PathBuf};

use super::corpus::CorpusGenerator;
use super::records::{Sample, ShardWriter};
use super::special::{CLS, SEP};
use super::tokenizer::BpeTokenizer;
use crate::config::DataConfig;
use crate::Result;

/// Outcome of a preprocessing run.
#[derive(Clone, Debug)]
pub struct PreprocessStats {
    pub samples: usize,
    pub shards: Vec<PathBuf>,
    /// Raw (JSONL + hex + metadata) footprint of the corpus.
    pub raw_bytes: u64,
    /// Packed tokenized footprint actually written.
    pub tokenized_bytes: u64,
    /// Mean tokens per raw byte (BPE compression diagnostic).
    pub tokens_per_byte: f64,
}

impl PreprocessStats {
    /// The headline rec-1 number: fraction of storage eliminated.
    pub fn reduction(&self) -> f64 {
        1.0 - self.tokenized_bytes as f64 / self.raw_bytes as f64
    }
}

/// Train a tokenizer on a deterministic sample of the corpus.
pub fn train_tokenizer(gen: &CorpusGenerator, vocab: usize, probe: usize)
    -> Result<BpeTokenizer> {
    let probe = probe.min(gen.samples).max(1);
    let sample_fns: Vec<Vec<u8>> = (0..probe)
        .map(|i| gen.generate(i * gen.samples / probe).bytes)
        .collect();
    let refs: Vec<&[u8]> = sample_fns.iter().map(|v| v.as_slice()).collect();
    BpeTokenizer::train(refs, vocab)
}

/// Tokenize one function into a fixed-length training sample:
/// `[CLS] tokens… [SEP]`, truncated/padded to `seq`.
pub fn encode_sample(tok: &BpeTokenizer, bytes: &[u8], seq: usize)
    -> Sample {
    let mut ids = Vec::with_capacity(seq);
    ids.push(CLS);
    let body = tok.encode(bytes);
    let room = seq - 2;
    ids.extend(body.iter().take(room).copied());
    ids.push(SEP);
    Sample::from_tokens(&ids, seq)
}

/// Full preprocessing pass: generate the corpus, tokenize, write shards
/// under `outdir`, account for raw vs packed bytes.
pub fn preprocess_corpus(cfg: &DataConfig, seq: usize, seed: u64,
                         outdir: &Path) -> Result<PreprocessStats> {
    let gen = CorpusGenerator::from_config(cfg, seed);
    let tok = train_tokenizer(&gen, cfg.tokenizer_vocab, 64)?;
    tok.save(&outdir.join("tokenizer.json"))?;

    let mut shards = Vec::new();
    let mut raw_bytes = 0u64;
    let mut tokenized_bytes = 0u64;
    let mut token_count = 0u64;
    let mut body_bytes = 0u64;

    let mut shard_idx = 0usize;
    let mut writer: Option<ShardWriter> = None;
    let mut in_shard = 0usize;
    for i in 0..cfg.corpus_samples {
        let f = gen.generate(i);
        raw_bytes += CorpusGenerator::raw_json_line(&f).len() as u64;
        let sample = encode_sample(&tok, &f.bytes, seq);
        token_count += sample.len as u64;
        body_bytes += f.bytes.len() as u64;
        if writer.is_none() {
            let path = outdir.join(format!("shard-{shard_idx:05}.bin"));
            writer = Some(ShardWriter::create(&path, seq)?);
            shards.push(path);
            in_shard = 0;
        }
        writer.as_mut().unwrap().write(&sample)?;
        in_shard += 1;
        if in_shard == cfg.samples_per_shard {
            tokenized_bytes += writer.take().unwrap().finish()?;
            shard_idx += 1;
        }
    }
    if let Some(w) = writer {
        tokenized_bytes += w.finish()?;
    }

    Ok(PreprocessStats {
        samples: cfg.corpus_samples,
        shards,
        raw_bytes,
        tokenized_bytes,
        tokens_per_byte: token_count as f64 / body_bytes.max(1) as f64,
    })
}

/// Paper-scale extrapolation of rec 1 without writing paper-scale data:
/// probe the raw format and the tokenized sample size, scale to
/// `total_samples`.
pub fn extrapolate_reduction(cfg: &DataConfig, seq: usize, seed: u64,
                             total_samples: usize) -> Result<(u64, u64)> {
    let gen = CorpusGenerator::from_config(cfg, seed);
    let raw_per = gen.estimated_raw_bytes(64) / gen.samples as u64;
    let packed_per = Sample::disk_bytes(seq);
    Ok((
        raw_per * total_samples as u64,
        packed_per * total_samples as u64,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StagingPolicy;

    fn cfg(samples: usize) -> DataConfig {
        DataConfig {
            corpus_samples: samples,
            fn_size_mu: 6.5, // small functions keep the test fast
            fn_size_sigma: 0.6,
            tokenizer_vocab: 300,
            mask_prob: 0.15,
            staging: StagingPolicy::LocalCopy,
            loaders_per_gpu: 1,
            prefetch_batches: 2,
            samples_per_shard: 64,
            cache_mb: 16.0,
            shuffle_window: 64,
            prefetch: true,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("txgain-prep-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_expected_shards_and_stats() {
        let dir = tmpdir("basic");
        let stats = preprocess_corpus(&cfg(150), 64, 11, &dir).unwrap();
        assert_eq!(stats.samples, 150);
        assert_eq!(stats.shards.len(), 3); // ceil(150/64)
        // every sample is readable back
        let mut total = 0;
        for p in &stats.shards {
            let mut r = crate::data::ShardReader::open(p).unwrap();
            assert_eq!(r.read_all().unwrap().len(), r.len());
            total += r.len();
        }
        assert_eq!(total, 150);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reduction_is_large_like_the_paper() {
        let dir = tmpdir("reduction");
        // realistic function sizes => big raw JSONL, small packed shards
        let mut c = cfg(60);
        c.fn_size_mu = 8.0;
        let stats = preprocess_corpus(&c, 128, 11, &dir).unwrap();
        assert!(
            stats.reduction() > 0.90,
            "reduction={:.3} (raw={} packed={})",
            stats.reduction(),
            stats.raw_bytes,
            stats.tokenized_bytes
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn encode_sample_layout() {
        let tok = BpeTokenizer::byte_level();
        let s = encode_sample(&tok, &[0xAA; 10], 16);
        assert_eq!(s.ids[0], CLS);
        assert_eq!(s.ids[11], SEP);
        assert_eq!(s.len, 12);
        // long input truncates but always ends with SEP
        let s = encode_sample(&tok, &[0xAA; 100], 16);
        assert_eq!(s.len, 16);
        assert_eq!(s.ids[15], SEP);
    }

    #[test]
    fn extrapolation_matches_paper_magnitude() {
        // paper: 202M samples, 2 TB raw -> 25 GB packed at seq 512…
        // our raw model ~9.9 KB/sample and packed 2+2*seq bytes
        let (raw, packed) =
            extrapolate_reduction(&DataConfig {
                fn_size_mu: 8.5,
                fn_size_sigma: 1.0,
                ..cfg(64)
            }, 64, 11, 202_000_000).unwrap();
        assert!(raw > 1_500_000_000_000, "raw={raw}");
        let red = 1.0 - packed as f64 / raw as f64;
        assert!(red > 0.98, "reduction={red}");
    }
}
