//! Renderers for the paper's artifacts.
//!
//! Table I is literature metadata (frontier-model releases) — there is
//! nothing to measure, so it is reproduced verbatim for completeness.
//! Fig. 1 is the scaling study; `fig1_table` renders a sweep of
//! [`crate::perfmodel::SimResult`] rows in the same shape (throughput vs
//! node count, one series per model size).

use crate::perfmodel::SimResult;
use crate::util::csv::CsvWriter;

use super::table::Table;

/// Paper Table I: frontier models (static metadata).
pub fn tab1_frontier_models() -> Table {
    let mut t = Table::new(
        "TABLE I — FRONTIER MODELS (paper, static metadata)",
        vec!["Company", "Model", "Release Date"],
    );
    for (c, m, d) in [
        ("OpenAI", "GPT-4.5", "February, 2025"),
        ("Google", "Gemini 2.5", "July, 2025"),
        ("Anthropic", "Claude 3.5 Sonnet", "June, 2024"),
        ("xAI", "Grok 3", "February, 2025"),
        ("Mistral AI", "Medium 3", "May, 2025"),
        ("DeepSeek", "R1", "January, 2025"),
    ] {
        t.row(&[c, m, d]);
    }
    t
}

/// Fig. 1 as a table: one row per node count, throughput + scaling
/// efficiency + the step-anatomy columns behind rec 4. An empty sweep
/// renders as an empty table (headers only), not a panic.
pub fn fig1_table(model_name: &str, sweep: &[SimResult]) -> Table {
    let mut t = Table::new(
        &format!("FIG. 1 — pretraining scaling performance ({model_name})"),
        vec!["nodes", "gpus", "batch/gpu", "samples/s", "scale-eff",
             "step(ms)", "compute(ms)", "comm-exposed(ms)", "wire/step",
             "io/step", "grad-mem/rank", "opt-mem/rank", "gpu-util",
             "plan"],
    );
    let Some(base) = sweep.first() else {
        return t;
    };
    for r in sweep {
        let ideal = base.samples_per_sec
            * (r.world as f64 / base.world as f64);
        t.row(&[
            r.nodes.to_string(),
            r.world.to_string(),
            r.batch_per_gpu.to_string(),
            format!("{:.0}", r.samples_per_sec),
            format!("{:.3}", r.samples_per_sec / ideal),
            format!("{:.1}", r.step_secs * 1e3),
            format!("{:.1}", r.compute_secs * 1e3),
            format!("{:.1}", r.comm_exposed_secs * 1e3),
            format!("{:.1}MB", r.wire_bytes_per_rank / 1e6),
            format!("{:.1}MB", r.loader_bytes_per_step / 1e6),
            format!("{:.1}MB", r.grad_bytes_per_rank / 1e6),
            format!("{:.1}MB", r.opt_bytes_per_rank / 1e6),
            format!("{:.3}", r.gpu_util),
            plan_cell(r),
        ]);
    }
    t
}

/// The auto-tuner's chosen plan for a row: `algorithm/bucketMB` (plus
/// `+firstMB` when a smaller first bucket was picked), or `-` when the
/// run used the configured knobs as-is.
fn plan_cell(r: &SimResult) -> String {
    match &r.tuned {
        Some(p) if p.first_bucket_mb > 0.0 => {
            format!("{}/{:.0}+{:.0}MB", p.algorithm.as_str(),
                    p.bucket_mb, p.first_bucket_mb)
        }
        Some(p) => {
            format!("{}/{:.0}MB", p.algorithm.as_str(), p.bucket_mb)
        }
        None => "-".into(),
    }
}

/// Fig. 1 as CSV (for external plotting).
pub fn fig1_csv(series: &[(&str, Vec<SimResult>)]) -> CsvWriter {
    let mut w = CsvWriter::new(vec![
        "model", "nodes", "gpus", "batch_per_gpu", "samples_per_sec",
        "step_secs", "compute_secs", "comm_secs", "comm_exposed_secs",
        "wire_bytes_per_rank", "loader_bytes_per_step",
        "grad_bytes_per_rank", "opt_bytes_per_rank",
        "mem_headroom_bytes", "gpu_util", "tuned_plan",
    ]);
    for (name, sweep) in series {
        for r in sweep {
            w.row(&[
                name.to_string(),
                r.nodes.to_string(),
                r.world.to_string(),
                r.batch_per_gpu.to_string(),
                format!("{:.2}", r.samples_per_sec),
                format!("{:.6}", r.step_secs),
                format!("{:.6}", r.compute_secs),
                format!("{:.6}", r.comm_secs),
                format!("{:.6}", r.comm_exposed_secs),
                format!("{:.0}", r.wire_bytes_per_rank),
                format!("{:.0}", r.loader_bytes_per_step),
                format!("{:.0}", r.grad_bytes_per_rank),
                format!("{:.0}", r.opt_bytes_per_rank),
                format!("{:.0}", r.mem_headroom_bytes),
                format!("{:.4}", r.gpu_util),
                plan_cell(r),
            ]);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::perfmodel::sweep_nodes;

    #[test]
    fn tab1_has_six_models() {
        let t = tab1_frontier_models();
        assert_eq!(t.len(), 6);
        assert!(t.render().contains("Claude 3.5 Sonnet"));
    }

    #[test]
    fn fig1_empty_sweep_renders_empty_table() {
        // regression: used to index sweep[0] and panic
        let t = fig1_table("bert-120m", &[]);
        assert_eq!(t.len(), 0);
        assert!(t.render().contains("FIG. 1"));
        let csv = fig1_csv(&[("bert-120m", Vec::new())]);
        assert_eq!(csv.len(), 0);
    }

    #[test]
    fn fig1_renders_sweep() {
        let cfg = presets::paper_full_scale();
        let sweep = sweep_nodes(&cfg, &[1, 2, 4]);
        let t = fig1_table("bert-120m", &sweep);
        assert_eq!(t.len(), 3);
        let csv = fig1_csv(&[("bert-120m", sweep)]);
        assert_eq!(csv.len(), 3);
    }

    #[test]
    fn fig1_reports_wire_traffic() {
        // the measured-vs-modeled cross-check column: wire bytes per
        // rank appear in both the table and the CSV
        let cfg = presets::paper_full_scale();
        let sweep = sweep_nodes(&cfg, &[1, 128]);
        let s = fig1_table("bert-120m", &sweep).render();
        assert!(s.contains("wire/step"), "missing column: {s}");
        let csv = fig1_csv(&[("bert-120m", sweep.clone())]).to_string();
        assert!(csv.contains("wire_bytes_per_rank"));
        // one node moves nothing inter-node; 128 nodes ~2(n-1)/n·bf16
        assert_eq!(sweep[0].wire_bytes_per_rank, 0.0);
        assert!(sweep[1].wire_bytes_per_rank > 0.0);
    }

    #[test]
    fn fig1_reports_loader_stream() {
        // the data-plane cross-check column: modeled disk bytes per
        // step appear in both table and CSV, matching the trainer's
        // measured loader_bytes column shape
        let cfg = presets::paper_full_scale();
        let sweep = sweep_nodes(&cfg, &[1, 128]);
        let s = fig1_table("bert-120m", &sweep).render();
        assert!(s.contains("io/step"), "missing column: {s}");
        let csv = fig1_csv(&[("bert-120m", sweep.clone())]).to_string();
        assert!(csv.contains("loader_bytes_per_step"));
        // ample default cache: one sample's bytes per sample
        let expect = cfg.training.batch_per_gpu as f64
            * (2 + 2 * cfg.model.seq) as f64;
        assert!((sweep[0].loader_bytes_per_step - expect).abs() < 1e-6);
    }

    #[test]
    fn fig1_reports_the_tuned_plan() {
        // with auto_tune on a hier transport, the chosen plan shows up
        // in the table and CSV; without it the column reads "-"
        let mut cfg = presets::paper_full_scale();
        cfg.cluster.nodes = 2;
        cfg.cluster.gpus_per_node = 4;
        cfg.training.transport = "hier".into();
        cfg.training.auto_tune = true;
        let sweep = sweep_nodes(&cfg, &[2]);
        let s = fig1_table("bert-120m", &sweep).render();
        assert!(s.contains("plan"), "missing column: {s}");
        assert!(s.contains("hierarchical/"), "plan not rendered: {s}");
        let csv = fig1_csv(&[("bert-120m", sweep)]).to_string();
        assert!(csv.contains("tuned_plan"));
        assert!(csv.contains("hierarchical/"));
        cfg.training.auto_tune = false;
        let plain = sweep_nodes(&cfg, &[2]);
        assert!(plain[0].tuned.is_none());
    }

    #[test]
    fn fig1_surfaces_per_rank_gradient_memory() {
        let mut cfg = presets::paper_full_scale();
        cfg.training.zero_stage = 2;
        let sweep = sweep_nodes(&cfg, &[1, 128]);
        let s = fig1_table("bert-120m", &sweep).render();
        assert!(s.contains("grad-mem/rank"), "missing column: {s}");
        let csv = fig1_csv(&[("bert-120m", sweep.clone())]).to_string();
        assert!(csv.contains("grad_bytes_per_rank"));
        // stage 2 shards the gradient: 256 GPUs hold ~1/256 each
        assert!(sweep[1].grad_bytes_per_rank
                < sweep[0].grad_bytes_per_rank / 100.0);
        // stages 0/1 keep it replicated (flat across the sweep)
        cfg.training.zero_stage = 1;
        let flat = sweep_nodes(&cfg, &[1, 128]);
        assert_eq!(flat[0].grad_bytes_per_rank,
                   flat[1].grad_bytes_per_rank);
    }

    #[test]
    fn fig1_surfaces_per_rank_optimizer_memory() {
        let mut cfg = presets::paper_full_scale();
        cfg.training.zero_stage = 1;
        let sweep = sweep_nodes(&cfg, &[1, 128]);
        let s = fig1_table("bert-120m", &sweep).render();
        assert!(s.contains("opt-mem/rank"), "missing column: {s}");
        // at 128 nodes (256 GPUs) the 120M model's sharded moments are
        // ~3.4 MB/rank vs ~870 MB replicated — both rows must show MB
        assert!(s.contains("MB"));
        assert!(sweep[1].opt_bytes_per_rank
                < sweep[0].opt_bytes_per_rank / 100.0);
    }
}
