//! Column-aligned ASCII table renderer.

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: Vec<&str>) -> Table {
        Table {
            title: title.to_string(),
            header: header.into_iter().map(str::to_string).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) -> &mut Self {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows
            .push(cells.iter().map(|c| c.to_string()).collect());
        self
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        let _ = ncol;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", vec!["nodes", "samples/s"]);
        t.row(&["1", "100"]);
        t.row(&["128", "12000.5"]);
        let s = t.render();
        assert!(s.contains("T\n"));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines same width
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
        assert_eq!(t.len(), 2);
    }
}
