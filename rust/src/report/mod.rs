//! Report rendering: aligned ASCII tables, simple charts, and the
//! paper-artifact renderers (Table I, Fig. 1) shared by the benches and
//! examples.

pub mod paper;
pub mod table;

pub use paper::{fig1_table, tab1_frontier_models};
pub use table::Table;
