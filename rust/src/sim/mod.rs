//! Discrete-event simulation substrate.
//!
//! Two cooperating pieces:
//! - [`engine`]: a minimal event queue (time-ordered closures) used to
//!   drive timelines (training steps, prefetch pipelines).
//! - [`flow`]: a max-min fair-share *flow-level* network model — shared
//!   resources (the Lustre array, the core switch, a node's NIC or SSD)
//!   divide bandwidth among concurrent transfers, with rates recomputed
//!   at every arrival/completion. This is what makes the paper's
//!   recommendation-2 contention cliff appear at scale.

pub mod engine;
pub mod flow;

pub use engine::Engine;
pub use flow::{FlowNet, LinkId};
