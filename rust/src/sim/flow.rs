//! Max-min fair-share flow network.
//!
//! Links have capacities (bytes/s); a flow traverses a set of links and
//! carries a byte count. Rates follow progressive filling (the classical
//! max-min allocation): repeatedly saturate the most-contended link,
//! freeze its flows at the fair share, remove, repeat. Events are flow
//! arrivals/completions; rates are recomputed at each.
//!
//! This models exactly the storage behaviour behind recommendation 2:
//! N clients reading through per-client NIC caps from a shared array
//! whose aggregate bandwidth saturates as N grows.

/// Index of a link in the network.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LinkId(pub usize);

/// Index of a flow (returned by [`FlowNet::add_flow`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FlowId(pub usize);

#[derive(Clone, Debug)]
struct Flow {
    path: Vec<LinkId>,
    bytes_left: f64,
    start: f64,
    finish: Option<f64>,
}

/// A static set of flows simulated to completion.
#[derive(Default)]
pub struct FlowNet {
    capacities: Vec<f64>,
    flows: Vec<Flow>,
}

impl FlowNet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a link with `capacity` bytes/second.
    pub fn add_link(&mut self, capacity: f64) -> LinkId {
        assert!(capacity > 0.0, "link capacity must be positive");
        self.capacities.push(capacity);
        LinkId(self.capacities.len() - 1)
    }

    /// Add a flow of `bytes` over `path`, starting at time `start`.
    pub fn add_flow(&mut self, path: Vec<LinkId>, bytes: f64, start: f64)
        -> FlowId {
        assert!(!path.is_empty(), "flow needs at least one link");
        assert!(bytes >= 0.0);
        self.flows.push(Flow { path, bytes_left: bytes, start,
                               finish: if bytes == 0.0 { Some(start) }
                                       else { None } });
        FlowId(self.flows.len() - 1)
    }

    /// Max-min rates for the given set of active flow indices.
    fn rates(&self, active: &[usize]) -> Vec<f64> {
        let n = self.capacities.len();
        let mut residual = self.capacities.clone();
        let mut link_flows: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (ai, &fi) in active.iter().enumerate() {
            for l in &self.flows[fi].path {
                link_flows[l.0].push(ai);
            }
        }
        let mut rate = vec![f64::INFINITY; active.len()];
        let mut unassigned: Vec<bool> = vec![true; active.len()];
        let mut remaining_on_link: Vec<usize> =
            link_flows.iter().map(|v| v.len()).collect();
        loop {
            // most-contended link = min fair share among links that still
            // carry unassigned flows
            let mut best: Option<(usize, f64)> = None;
            for l in 0..n {
                if remaining_on_link[l] == 0 {
                    continue;
                }
                let share = residual[l] / remaining_on_link[l] as f64;
                if best.map_or(true, |(_, s)| share < s) {
                    best = Some((l, share));
                }
            }
            let Some((l, share)) = best else { break };
            // freeze all unassigned flows through l at `share`
            let frozen: Vec<usize> = link_flows[l]
                .iter()
                .copied()
                .filter(|&ai| unassigned[ai])
                .collect();
            for &ai in &frozen {
                rate[ai] = share;
                unassigned[ai] = false;
                // remove from every link it crosses
                for pl in &self.flows[active[ai]].path {
                    residual[pl.0] -= share;
                    remaining_on_link[pl.0] -= 1;
                }
            }
            if frozen.is_empty() {
                // defensive: should not happen
                break;
            }
        }
        for r in &mut rate {
            if !r.is_finite() {
                *r = 0.0;
            }
        }
        rate
    }

    /// Simulate all flows to completion; returns per-flow finish times.
    pub fn run(&mut self) -> Vec<f64> {
        let mut t = 0.0_f64;
        loop {
            let active: Vec<usize> = (0..self.flows.len())
                .filter(|&i| {
                    self.flows[i].finish.is_none() && self.flows[i].start <= t
                })
                .collect();
            let next_arrival = self
                .flows
                .iter()
                .filter(|f| f.finish.is_none() && f.start > t)
                .map(|f| f.start)
                .fold(f64::INFINITY, f64::min);
            if active.is_empty() {
                if next_arrival.is_finite() {
                    t = next_arrival;
                    continue;
                }
                break;
            }
            let rates = self.rates(&active);
            // earliest completion among active flows
            let mut dt = f64::INFINITY;
            for (ai, &fi) in active.iter().enumerate() {
                if rates[ai] > 0.0 {
                    dt = dt.min(self.flows[fi].bytes_left / rates[ai]);
                }
            }
            if next_arrival.is_finite() {
                dt = dt.min(next_arrival - t);
            }
            if !dt.is_finite() {
                // active flows but zero rates and no arrivals: stuck
                panic!("flow network deadlock: active flows with zero rate");
            }
            for (ai, &fi) in active.iter().enumerate() {
                let f = &mut self.flows[fi];
                f.bytes_left -= rates[ai] * dt;
                if f.bytes_left <= 1e-6 {
                    f.bytes_left = 0.0;
                    f.finish = Some(t + dt);
                }
            }
            t += dt;
        }
        self.flows
            .iter()
            .map(|f| f.finish.expect("flow did not finish"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_takes_bytes_over_capacity() {
        let mut net = FlowNet::new();
        let l = net.add_link(100.0);
        net.add_flow(vec![l], 1000.0, 0.0);
        let t = net.run();
        assert!((t[0] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut net = FlowNet::new();
        let l = net.add_link(100.0);
        net.add_flow(vec![l], 500.0, 0.0);
        net.add_flow(vec![l], 500.0, 0.0);
        let t = net.run();
        // each gets 50 B/s => both finish at 10s
        assert!((t[0] - 10.0).abs() < 1e-9);
        assert!((t[1] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn short_flow_releases_bandwidth() {
        let mut net = FlowNet::new();
        let l = net.add_link(100.0);
        net.add_flow(vec![l], 100.0, 0.0); // short
        net.add_flow(vec![l], 450.0, 0.0); // long
        let t = net.run();
        // shared at 50 B/s until short ends at t=2 (100B); long then has
        // 350B left at 100 B/s: ends at 2 + 3.5 = 5.5
        assert!((t[0] - 2.0).abs() < 1e-9, "{t:?}");
        assert!((t[1] - 5.5).abs() < 1e-9, "{t:?}");
    }

    #[test]
    fn per_client_cap_binds_before_shared_array() {
        // 2 clients, each capped at 10 B/s, shared array 100 B/s: the
        // clients are the bottleneck; array is underused.
        let mut net = FlowNet::new();
        let array = net.add_link(100.0);
        let c1 = net.add_link(10.0);
        let c2 = net.add_link(10.0);
        net.add_flow(vec![array, c1], 100.0, 0.0);
        net.add_flow(vec![array, c2], 100.0, 0.0);
        let t = net.run();
        assert!((t[0] - 10.0).abs() < 1e-9);
        assert!((t[1] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn shared_array_saturates_with_many_clients() {
        // 20 clients of 10 B/s each through a 100 B/s array: fair share
        // is 5 B/s per client — the rec-2 contention regime.
        let mut net = FlowNet::new();
        let array = net.add_link(100.0);
        for _ in 0..20 {
            let c = net.add_link(10.0);
            net.add_flow(vec![array, c], 50.0, 0.0);
        }
        let t = net.run();
        for ti in t {
            assert!((ti - 10.0).abs() < 1e-9);
        }
    }

    #[test]
    fn late_arrival_reduces_rates() {
        let mut net = FlowNet::new();
        let l = net.add_link(100.0);
        net.add_flow(vec![l], 1000.0, 0.0);
        net.add_flow(vec![l], 250.0, 5.0);
        let t = net.run();
        // flow0: 500B done by t=5, then shares 50B/s; flow1 needs 5s
        // (250/50) -> ends at 10; flow0 has 250 left at t=10, full rate
        // -> ends 12.5
        assert!((t[1] - 10.0).abs() < 1e-6, "{t:?}");
        assert!((t[0] - 12.5).abs() < 1e-6, "{t:?}");
    }

    #[test]
    fn zero_byte_flow_finishes_at_start() {
        let mut net = FlowNet::new();
        let l = net.add_link(10.0);
        net.add_flow(vec![l], 0.0, 3.0);
        let t = net.run();
        assert_eq!(t[0], 3.0);
    }
}
