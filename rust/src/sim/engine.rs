//! Minimal discrete-event engine: closures scheduled at simulated times.
//!
//! Determinism: ties in time break by insertion sequence number, so a
//! given schedule always executes in one order.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds.
pub type SimTime = f64;

type Callback = Box<dyn FnOnce(&mut Engine)>;

struct Scheduled {
    time: SimTime,
    seq: u64,
    cb: Callback,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The event engine. `run` drains the queue in time order.
#[derive(Default)]
pub struct Engine {
    queue: BinaryHeap<Scheduled>,
    time: SimTime,
    seq: u64,
    executed: u64,
}

impl Engine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulated time.
    pub fn now(&self) -> SimTime {
        self.time
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Schedule `cb` at absolute time `at` (>= now).
    pub fn at<F: FnOnce(&mut Engine) + 'static>(&mut self, at: SimTime,
                                                cb: F) {
        debug_assert!(at >= self.time, "cannot schedule in the past");
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Scheduled { time: at.max(self.time), seq,
                                    cb: Box::new(cb) });
    }

    /// Schedule `cb` after a delay from now.
    pub fn after<F: FnOnce(&mut Engine) + 'static>(&mut self, delay: SimTime,
                                                   cb: F) {
        let t = self.time + delay.max(0.0);
        self.at(t, cb);
    }

    /// Run until the queue is empty; returns the final time.
    pub fn run(&mut self) -> SimTime {
        while let Some(ev) = self.queue.pop() {
            self.time = ev.time;
            self.executed += 1;
            (ev.cb)(self);
        }
        self.time
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn executes_in_time_order() {
        let order = Rc::new(RefCell::new(Vec::new()));
        let mut e = Engine::new();
        for (t, tag) in [(3.0, 'c'), (1.0, 'a'), (2.0, 'b')] {
            let o = order.clone();
            e.at(t, move |_| o.borrow_mut().push(tag));
        }
        let end = e.run();
        assert_eq!(*order.borrow(), vec!['a', 'b', 'c']);
        assert_eq!(end, 3.0);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let order = Rc::new(RefCell::new(Vec::new()));
        let mut e = Engine::new();
        for tag in 0..5 {
            let o = order.clone();
            e.at(1.0, move |_| o.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn events_can_schedule_events() {
        let hits = Rc::new(RefCell::new(0u32));
        let mut e = Engine::new();
        let h = hits.clone();
        e.at(1.0, move |e| {
            *h.borrow_mut() += 1;
            let h2 = h.clone();
            e.after(2.0, move |e| {
                *h2.borrow_mut() += 1;
                assert_eq!(e.now(), 3.0);
            });
        });
        e.run();
        assert_eq!(*hits.borrow(), 2);
        assert_eq!(e.executed(), 2);
    }
}
