//! Exhaustive interleaving checks of the shm transport's SPSC ring
//! protocol (`collectives::transport::spsc`) against the simulated
//! weak-memory model in `util::interleave`.
//!
//! The *production* protocol functions (`offer` / `poll`) run here
//! unchanged, over a [`RingMem`] backed by simulated atomics and a
//! simulated *plain* (racy) slot — deliberately so: the shm backend's
//! per-slot mutex is aliasing-only, and these tests prove all ordering
//! really does come from the head/tail/alive protocol. The explorer
//! covers every schedule and every allowed weak-memory read, so a pass
//! is a proof over the bounded model, not a lucky run.
//!
//! The last two tests are the acceptance criterion for the checker
//! itself: seeding a deliberate bug (dropping the head store's
//! `Release`, or the dying peer's `Release` on the alive flag) must
//! make the checker fail with a concrete interleaving — a data race in
//! the first case, a lost final message in the second.

use txgain::collectives::transport::spsc::{
    offer, poll, MemOrd, RecvPoll, RingMem, SendPoll,
};
use txgain::util::interleave::{
    explore, Atom, Kind, MemOrder, Model, Options, Plain, Thr,
};

fn opts() -> Options {
    Options {
        max_schedules: 300_000,
        max_depth: 10_000,
        max_ops: 300_000,
    }
}

fn conv(o: MemOrd) -> MemOrder {
    match o {
        MemOrd::Relaxed => MemOrder::Relaxed,
        MemOrd::Acquire => MemOrder::Acquire,
        MemOrd::Release => MemOrder::Release,
    }
}

/// The simulated ring's locations: head/tail/alive as model atomics,
/// the single payload slot as *plain* memory so any access the
/// protocol fails to order is reported as a data race.
#[derive(Clone, Copy)]
struct RingLoc {
    head: Atom,
    tail: Atom,
    alive: Atom,
    slot: Plain,
}

fn ring_locs(m: &mut Model) -> RingLoc {
    RingLoc {
        head: m.atom(0),
        tail: m.atom(0),
        alive: m.atom(1),
        slot: m.plain(0),
    }
}

/// Capacity-1 [`RingMem`] over the simulated memory. Payload values
/// are nonzero by convention; 0 marks an empty slot.
struct SimRing<'a> {
    t: &'a mut Thr,
    l: RingLoc,
    /// Seeded bug for the must-fail test: publish the head with
    /// `Relaxed` instead of the ordering the protocol passed.
    weaken_head_store: bool,
}

impl RingMem for SimRing<'_> {
    type Payload = u64;

    fn capacity(&self) -> usize {
        1
    }
    fn load_head(&mut self, ord: MemOrd) -> usize {
        self.t.load(self.l.head, conv(ord)) as usize
    }
    fn store_head(&mut self, v: usize, ord: MemOrd) {
        let eff = if self.weaken_head_store {
            MemOrder::Relaxed
        } else {
            conv(ord)
        };
        self.t.store(self.l.head, v as u64, eff);
    }
    fn load_tail(&mut self, ord: MemOrd) -> usize {
        self.t.load(self.l.tail, conv(ord)) as usize
    }
    fn store_tail(&mut self, v: usize, ord: MemOrd) {
        self.t.store(self.l.tail, v as u64, conv(ord));
    }
    fn load_alive(&mut self, ord: MemOrd) -> bool {
        self.t.load(self.l.alive, conv(ord)) != 0
    }
    fn slot_put(&mut self, _idx: usize, item: u64) {
        self.t.write(self.l.slot, item);
    }
    fn slot_take(&mut self, _idx: usize) -> Option<u64> {
        let v = self.t.read(self.l.slot);
        if v == 0 {
            None
        } else {
            self.t.write(self.l.slot, 0);
            Some(v)
        }
    }
}

/// The documented Release/Acquire pairing really does publish the slot
/// write: across every schedule the consumer receives the payload,
/// the racy slot never trips the race detector, and no interleaving
/// deadlocks.
#[test]
fn publish_is_race_free_and_always_delivers() {
    let rep = explore(&opts(), |m| {
        let l = ring_locs(m);
        let got = m.atom(0);
        m.thread(move |t| {
            let mut r =
                SimRing { t, l, weaken_head_store: false };
            match offer(&mut r, || 7) {
                SendPoll::Sent => {}
                other => {
                    panic!("cap-1 empty ring refused publish: {other:?}")
                }
            }
        });
        m.thread(move |t| loop {
            let mut r =
                SimRing { t: &mut *t, l, weaken_head_store: false };
            match poll(&mut r).expect("ring corrupt") {
                RecvPoll::Got(v) => {
                    t.store(got, v, MemOrder::Relaxed);
                    break;
                }
                RecvPoll::Empty => t.spin_yield(),
                RecvPoll::PeerDead => {
                    panic!("peer reported dead while alive")
                }
            }
        });
        m.check(move |f| {
            if f.atom(got) == 7 {
                Ok(())
            } else {
                Err(format!(
                    "consumer finished with {} instead of the \
                     published 7",
                    f.atom(got)
                ))
            }
        });
    })
    .unwrap_or_else(|v| panic!("ring protocol violation: {v}"));
    assert!(rep.schedules > 1, "explorer found only one schedule");
}

/// The dead-peer protocol (`poll`'s one extra drain after an Acquire
/// load of the dead flag) never loses the final message and never
/// hangs: on every schedule the consumer counts exactly one payload
/// and then terminates with `PeerDead`.
#[test]
fn peer_death_drains_final_message_then_reports_dead() {
    let rep = explore(&opts(), |m| {
        let l = ring_locs(m);
        let got = m.atom(0);
        m.thread(move |t| {
            let mut r =
                SimRing { t: &mut *t, l, weaken_head_store: false };
            assert!(matches!(offer(&mut r, || 7), SendPoll::Sent));
            // the dying rank's drop path: publish happens-before the
            // Release store of the liveness flag
            t.store(l.alive, 0, MemOrder::Release);
        });
        m.thread(move |t| {
            let mut count = 0u64;
            loop {
                let mut r = SimRing {
                    t: &mut *t,
                    l,
                    weaken_head_store: false,
                };
                match poll(&mut r).expect("ring corrupt") {
                    RecvPoll::Got(_) => count += 1,
                    RecvPoll::Empty => t.spin_yield(),
                    RecvPoll::PeerDead => break,
                }
            }
            t.store(got, count, MemOrder::Relaxed);
        });
        m.check(move |f| {
            if f.atom(got) == 1 {
                Ok(())
            } else {
                Err(format!(
                    "dead-peer drain delivered {} messages, \
                     expected exactly 1",
                    f.atom(got)
                ))
            }
        });
    })
    .unwrap_or_else(|v| panic!("dead-peer protocol violation: {v}"));
    assert!(rep.schedules > 1, "explorer found only one schedule");
}

/// Acceptance criterion for the checker: dropping the `Release` on the
/// producer's head store must be caught — the consumer can then read
/// the slot without a happens-before edge, a data race.
#[test]
fn dropping_head_release_is_caught_as_a_race() {
    let v = explore(&opts(), |m| {
        let l = ring_locs(m);
        let got = m.atom(0);
        m.thread(move |t| {
            let mut r = SimRing { t, l, weaken_head_store: true };
            let _ = offer(&mut r, || 7);
        });
        m.thread(move |t| loop {
            let mut r =
                SimRing { t: &mut *t, l, weaken_head_store: true };
            match poll(&mut r).expect("ring corrupt") {
                RecvPoll::Got(x) => {
                    t.store(got, x, MemOrder::Relaxed);
                    break;
                }
                RecvPoll::Empty => t.spin_yield(),
                RecvPoll::PeerDead => break,
            }
        });
    })
    .expect_err("a Relaxed head publish must be flagged");
    assert_eq!(
        v.kind,
        Kind::Race,
        "expected a slot data race, got: {v}"
    );
}

/// Acceptance criterion, second seeded bug: a dying peer that stores
/// its alive flag `Relaxed` breaks the drain guarantee — there is an
/// interleaving where the consumer sees `dead`, drains nothing, and
/// the final message is lost. The end-of-schedule invariant catches
/// it.
#[test]
fn dropping_alive_release_loses_the_final_message() {
    let v = explore(&opts(), |m| {
        let l = ring_locs(m);
        let got = m.atom(0);
        m.thread(move |t| {
            let mut r =
                SimRing { t: &mut *t, l, weaken_head_store: false };
            assert!(matches!(offer(&mut r, || 7), SendPoll::Sent));
            // seeded bug: death announced without Release
            t.store(l.alive, 0, MemOrder::Relaxed);
        });
        m.thread(move |t| {
            let mut count = 0u64;
            loop {
                let mut r = SimRing {
                    t: &mut *t,
                    l,
                    weaken_head_store: false,
                };
                match poll(&mut r).expect("ring corrupt") {
                    RecvPoll::Got(_) => count += 1,
                    RecvPoll::Empty => t.spin_yield(),
                    RecvPoll::PeerDead => break,
                }
            }
            t.store(got, count, MemOrder::Relaxed);
        });
        m.check(move |f| {
            if f.atom(got) == 1 {
                Ok(())
            } else {
                Err(format!(
                    "message lost: consumer saw {} messages",
                    f.atom(got)
                ))
            }
        });
    })
    .expect_err("a Relaxed alive store must lose a message on some \
                 schedule");
    assert_eq!(
        v.kind,
        Kind::Assert,
        "expected the lost-message invariant to fire, got: {v}"
    );
}
