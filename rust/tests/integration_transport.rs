//! Transport conformance suite: every backend behind the
//! `training.transport` knob must honor the same contract — selective
//! receive with out-of-order tag parking, payloads of any size,
//! graceful dead-peer errors, identical byte accounting, and (the one
//! that matters for training) bit-identical collective results against
//! the channel reference across worlds {2, 4, 8}.
//!
//! Structure: each check is a function over a [`Backend`]; the
//! `backend_suite!` macro stamps it out as `channel::*`, `shm::*` and
//! `tcp::*` tests, so `cargo test --test integration_transport tcp::`
//! runs one backend's suite in isolation (what `verify.sh` does).

use txgain::collectives::{allreduce, bucketed_all_gather,
                          bucketed_allreduce, bucketed_reduce_scatter,
                          Algorithm, AnyTransport, Backend, BucketPlan,
                          CollectiveKind, CommEngine, PendingBucket,
                          Transport, TransportStats};

/// Deterministic integer-valued inputs: sums over ≤8 ranks are exact
/// in f32, so bit-identity across backends/algorithms is well-defined.
fn inputs(world: usize, len: usize) -> Vec<Vec<f32>> {
    (0..world)
        .map(|r| {
            (0..len)
                .map(|i| ((r * 17 + i * 5) % 41) as f32 - 20.0)
                .collect()
        })
        .collect()
}

/// Run `op` on every rank of a fresh `backend` world; returns each
/// rank's buffer and transport stats.
fn run_world(
    backend: Backend,
    bufs: Vec<Vec<f32>>,
    op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>),
) -> Vec<(Vec<f32>, TransportStats)> {
    let world = bufs.len();
    std::thread::scope(|s| {
        backend
            .world(world)
            .unwrap()
            .into_iter()
            .zip(bufs)
            .enumerate()
            .map(|(rank, (mut c, mut buf))| {
                s.spawn(move || {
                    op(rank, world, &mut c, &mut buf);
                    (buf, c.stats())
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    })
}

mod suite {
    use super::*;

    pub fn out_of_order_tag_parking(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.send_slice(1, 1, &[1.0]).unwrap();
        c0.send_slice(1, 2, &[2.0]).unwrap();
        c0.send_slice(1, 1, &[3.0]).unwrap();
        // claiming tag 2 first must park (not drop or reorder) tag 1
        assert_eq!(c1.recv(0, 2).unwrap(), vec![2.0]);
        assert_eq!(c1.recv(0, 1).unwrap(), vec![1.0]);
        assert_eq!(c1.recv(0, 1).unwrap(), vec![3.0]);
    }

    pub fn large_and_empty_payloads(backend: Backend) {
        // 300k f32 = 1.2 MB: spans many TCP frames and far exceeds a
        // loopback socket buffer, so the sender genuinely streams
        let n = 300_000usize;
        let big: Vec<f32> = (0..n).map(|i| (i % 1013) as f32).collect();
        let expect = big.clone();
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        std::thread::scope(|s| {
            s.spawn(move || {
                c0.send_slice(1, 7, &big).unwrap();
                c0.send_slice(1, 8, &[]).unwrap();
            });
            s.spawn(move || {
                assert_eq!(c1.recv(0, 7).unwrap(), expect, "{backend}");
                assert!(c1.recv(0, 8).unwrap().is_empty(),
                        "{backend}: empty payload mangled");
            });
        });
    }

    pub fn dead_peer_recv_errors(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        drop(c0);
        assert!(c1.recv(0, 0).is_err(),
                "{backend}: recv from dead peer hung or succeeded");
    }

    pub fn dead_peer_send_errors(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        drop(c1);
        // buffered backends may absorb a bounded number of sends; the
        // error must surface within the in-flight window (plus, for
        // tcp, the kernel's RST round-trip)
        let mut failed = false;
        for _ in 0..200 {
            if c0.send_slice(1, 0, &[1.0; 64]).is_err() {
                failed = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(failed, "{backend}: send to dead peer never errored");
    }

    pub fn in_flight_messages_survive_peer_death(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.send_slice(1, 4, &[5.0, 6.0]).unwrap();
        drop(c0);
        assert_eq!(c1.recv(0, 4).unwrap(), vec![5.0, 6.0],
                   "{backend}: in-flight message lost with its sender");
        assert!(c1.recv(0, 4).is_err());
    }

    pub fn allreduce_bit_identical_to_channel(backend: Backend) {
        for world in [2usize, 4, 8] {
            for len in [13usize, 257] {
                for algo in [Algorithm::Ring, Algorithm::Tree] {
                    let op: fn(usize, usize, &mut AnyTransport,
                               &mut Vec<f32>) = match algo {
                        Algorithm::Ring => |_, _, c, buf| {
                            allreduce(Algorithm::Ring, c, buf).unwrap()
                        },
                        Algorithm::Tree => |_, _, c, buf| {
                            allreduce(Algorithm::Tree, c, buf).unwrap()
                        },
                    };
                    let got =
                        run_world(backend, inputs(world, len), op);
                    let want =
                        run_world(Backend::Channel, inputs(world, len),
                                  op);
                    for (r, ((g, gs), (w, ws))) in
                        got.iter().zip(&want).enumerate()
                    {
                        for (a, b) in g.iter().zip(w) {
                            assert_eq!(
                                a.to_bits(), b.to_bits(),
                                "{backend} {algo} world={world} \
                                 len={len} rank={r}: {a} != {b}");
                        }
                        // identical traffic accounting too
                        assert_eq!(gs, ws,
                                   "{backend} {algo} world={world} \
                                    len={len} rank={r}: stats differ");
                    }
                }
            }
        }
    }

    pub fn zero1_pipeline_bit_identical_to_channel(backend: Backend) {
        // the ZeRO-1 step skeleton: bucketed RS → nonlinear shard
        // update → bucketed AG. (Full AdamW equivalence vs the
        // replicated optimizer is proven over the channel backend in
        // integration_zero; here we prove the transport cannot change
        // the result.)
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |rank, world, c, buf| {
                let plan = BucketPlan::from_elems(buf.len(), 29);
                bucketed_reduce_scatter(Algorithm::Ring, c, buf, &plan)
                    .unwrap();
                for &(a, b) in &plan.rank_ranges(rank, world) {
                    for x in &mut buf[a..b] {
                        // nonlinear, order-sensitive "optimizer step"
                        *x = (*x * 0.5 + 1.0) / (x.abs() + 2.0);
                    }
                }
                bucketed_all_gather(Algorithm::Ring, c, buf, &plan)
                    .unwrap();
            };
        for world in [2usize, 4, 8] {
            let len = 103usize; // uneven vs every bucket/shard boundary
            let got = run_world(backend, inputs(world, len), op);
            let want =
                run_world(Backend::Channel, inputs(world, len), op);
            for (r, ((g, _), (w, _))) in
                got.iter().zip(&want).enumerate()
            {
                for (a, b) in g.iter().zip(w) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{backend} world={world} rank={r}: \
                                {a} != {b}");
                }
                // replicas agree with each other (the DDP invariant)
                assert_eq!(g, &got[0].0);
            }
        }
    }

    pub fn wire_accounting_matches_alpha_beta_model(backend: Backend) {
        // measured wire bytes for a flat ring all-reduce must equal
        // the α-β model's 2(R-1)/R × bf16 bytes — the cross-check the
        // Fig. 1 wire/step column rests on
        let world = 4usize;
        let len = 400usize; // divisible by world: exact formula
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Ring, c, buf).unwrap();
            };
        let out = run_world(backend, inputs(world, len), op);
        let elems = (2 * (world - 1) * (len / world)) as u64;
        for (r, (_, stats)) in out.iter().enumerate() {
            assert_eq!(stats.wire_bytes_sent, elems * 2,
                       "{backend} rank={r}: wire bytes");
            assert_eq!(stats.buffer_bytes_sent, elems * 4,
                       "{backend} rank={r}: buffer bytes");
            assert_eq!(stats.wire_bytes_recv, elems * 2,
                       "{backend} rank={r}: ring symmetry broken");
            assert_eq!(stats.msgs_sent, 2 * (world as u64 - 1));
        }
    }

    // ---- async conformance: the nonblocking face + the comm engine.

    pub fn nonblocking_ops_roundtrip(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        // empty wire: try_recv reports nothing without blocking
        assert!(c1.try_recv(0, 5).unwrap().is_none(), "{backend}");
        assert!(c0.try_send(1, 5, &[1.5, -2.0]).unwrap(), "{backend}");
        // poll until delivered (thread-backed backends need a moment)
        let mut got = None;
        for _ in 0..10_000 {
            if let Some(v) = c1.try_recv(0, 5).unwrap() {
                got = Some(v);
                break;
            }
            std::thread::yield_now();
        }
        assert_eq!(got, Some(vec![1.5, -2.0]), "{backend}");
        // tag parking holds for the nonblocking face too
        c0.send_slice(1, 1, &[1.0]).unwrap();
        c0.send_slice(1, 2, &[2.0]).unwrap();
        let mut two = None;
        for _ in 0..10_000 {
            if let Some(v) = c1.try_recv(0, 2).unwrap() {
                two = Some(v);
                break;
            }
            std::thread::yield_now();
        }
        assert_eq!(two, Some(vec![2.0]), "{backend}");
        assert_eq!(c1.recv(0, 1).unwrap(), vec![1.0], "{backend}");
        // sustained one-sided sending hits backpressure (Ok(false))
        // within a bounded number of attempts on every backend
        let payload = vec![1.0f32; 300_000];
        let mut accepted = 0usize;
        let mut saw_full = false;
        for _ in 0..64 {
            if c0.try_send(1, 9, &payload).unwrap() {
                accepted += 1;
            } else {
                saw_full = true;
                break;
            }
        }
        assert!(saw_full,
                "{backend}: try_send never reported backpressure \
                 ({accepted} accepted)");
        // everything accepted is still delivered, in order
        for _ in 0..accepted {
            assert_eq!(c1.recv(0, 9).unwrap().len(), 300_000,
                       "{backend}");
        }
    }

    pub fn engine_concurrent_buckets_bit_identical(backend: Backend) {
        // N concurrent outstanding buckets through the comm engine
        // complete bit-identical to the blocking bucketed path across
        // worlds {2, 4, 8} — the tentpole equivalence. The plan has an
        // uneven (smaller) first bucket, so the size-aware partition
        // is conformance-tested on every wire too.
        let len = 103usize;
        let plan_of =
            |n: usize| BucketPlan::from_elems_with_first(n, 23, 7);
        let blocking: fn(usize, usize, &mut AnyTransport,
                         &mut Vec<f32>) = |_, _, c, buf| {
            let plan = BucketPlan::from_elems_with_first(buf.len(), 23,
                                                         7);
            bucketed_allreduce(Algorithm::Ring, c, buf, &plan).unwrap();
        };
        for world in [2usize, 4, 8] {
            let want =
                run_world(Backend::Channel, inputs(world, len),
                          blocking);
            let plan = plan_of(len);
            let got: Vec<Vec<f32>> = std::thread::scope(|s| {
                backend
                    .world(world)
                    .unwrap()
                    .into_iter()
                    .zip(inputs(world, len))
                    .map(|(c, mut buf)| {
                        let plan = plan.clone();
                        s.spawn(move || {
                            let mut eng = CommEngine::new(c);
                            // every bucket in flight at once
                            let pend: Vec<(usize, PendingBucket)> =
                                plan.ready_order()
                                    .map(|i| {
                                        let (a, b) = plan.span(i);
                                        (i, eng.launch_bucket(
                                            Algorithm::Ring,
                                            CollectiveKind::Allreduce,
                                            buf[a..b].to_vec())
                                            .unwrap())
                                    })
                                    .collect();
                            for (i, p) in pend {
                                let (a, b) = plan.span(i);
                                let got = eng.wait(p).unwrap();
                                buf[a..b].copy_from_slice(&got);
                                eng.recycle(got);
                            }
                            buf
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            for (r, (g, (w, _))) in got.iter().zip(&want).enumerate() {
                for (a, b) in g.iter().zip(w) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{backend} world={world} rank={r}: \
                                {a} != {b}");
                }
                assert_eq!(g, &got[0], "replicas diverged");
            }
        }
    }

    pub fn engine_zero1_pipeline_bit_identical(backend: Backend) {
        // the engine-driven ZeRO-1 skeleton (concurrent RS buckets →
        // nonlinear shard update as each lands → concurrent AG
        // buckets) against the blocking reference — the exact overlap
        // schedule the trainer runs under `comm_engine`
        let len = 103usize;
        let blocking: fn(usize, usize, &mut AnyTransport,
                         &mut Vec<f32>) = |rank, world, c, buf| {
            let plan = BucketPlan::from_elems(buf.len(), 29);
            bucketed_reduce_scatter(Algorithm::Ring, c, buf, &plan)
                .unwrap();
            for &(a, b) in &plan.rank_ranges(rank, world) {
                for x in &mut buf[a..b] {
                    *x = (*x * 0.5 + 1.0) / (x.abs() + 2.0);
                }
            }
            bucketed_all_gather(Algorithm::Ring, c, buf, &plan).unwrap();
        };
        for world in [2usize, 4, 8] {
            let want =
                run_world(Backend::Channel, inputs(world, len),
                          blocking);
            let got: Vec<Vec<f32>> = std::thread::scope(|s| {
                backend
                    .world(world)
                    .unwrap()
                    .into_iter()
                    .zip(inputs(world, len))
                    .enumerate()
                    .map(|(rank, (c, mut buf))| {
                        s.spawn(move || {
                            let plan =
                                BucketPlan::from_elems(buf.len(), 29);
                            let mut eng = CommEngine::new(c);
                            let pend: Vec<(usize, PendingBucket)> =
                                plan.ready_order()
                                    .map(|i| {
                                        let (a, b) = plan.span(i);
                                        (i, eng.launch_bucket(
                                            Algorithm::Ring,
                                            CollectiveKind::ReduceScatter,
                                            buf[a..b].to_vec())
                                            .unwrap())
                                    })
                                    .collect();
                            // RS(k) wait → shard update → AG(k)
                            // launch, while RS(k+1..) is in flight
                            let mut ag = Vec::new();
                            for (i, p) in pend {
                                let (a, b) = plan.span(i);
                                let mut got = eng.wait(p).unwrap();
                                let (sa, sb) =
                                    plan.shard_span(i, rank, world);
                                for x in &mut got[sa - a..sb - a] {
                                    *x = (*x * 0.5 + 1.0)
                                        / (x.abs() + 2.0);
                                }
                                ag.push((i, eng.launch_bucket(
                                    Algorithm::Ring,
                                    CollectiveKind::AllGather, got)
                                    .unwrap()));
                            }
                            for (i, p) in ag {
                                let (a, b) = plan.span(i);
                                let got = eng.wait(p).unwrap();
                                buf[a..b].copy_from_slice(&got);
                                eng.recycle(got);
                            }
                            buf
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            for (r, (g, (w, _))) in got.iter().zip(&want).enumerate() {
                for (a, b) in g.iter().zip(w) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{backend} world={world} rank={r}: \
                                {a} != {b}");
                }
            }
        }
    }

    pub fn engine_dead_peer_mid_collective_errors(backend: Backend) {
        // a rank that dies with buckets in flight must surface as an
        // error on every surviving rank's wait — never a hang. (The
        // surviving engines tear down and cascade, so *all* waits
        // resolve.)
        let mut comms = backend.world(3).unwrap();
        let c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        std::thread::scope(|s| {
            s.spawn(move || drop(c2)); // rank 2 never participates
            for c in [c0, c1] {
                s.spawn(move || {
                    let mut eng = CommEngine::new(c);
                    let pend: Vec<PendingBucket> = (0..3)
                        .map(|k| {
                            eng.launch_bucket(
                                Algorithm::Ring,
                                CollectiveKind::Allreduce,
                                vec![k as f32; 32])
                                .unwrap()
                        })
                        .collect();
                    let mut failures = 0;
                    for p in pend {
                        if eng.wait(p).is_err() {
                            failures += 1;
                        }
                    }
                    assert!(failures > 0,
                            "{backend}: no in-flight bucket reported \
                             the dead peer");
                });
            }
        });
    }

    pub fn bucketed_matches_monolithic(backend: Backend) {
        // bucketing must not change the result on any transport
        let world = 4usize;
        let len = 230usize;
        let mono: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Ring, c, buf).unwrap();
            };
        let bucketed: fn(usize, usize, &mut AnyTransport,
                         &mut Vec<f32>) = |_, _, c, buf| {
            let plan = BucketPlan::from_elems(buf.len(), 37);
            bucketed_allreduce(Algorithm::Ring, c, buf, &plan).unwrap();
        };
        let a = run_world(backend, inputs(world, len), mono);
        let b = run_world(backend, inputs(world, len), bucketed);
        for ((x, _), (y, _)) in a.iter().zip(&b) {
            for (p, q) in x.iter().zip(y) {
                assert_eq!(p.to_bits(), q.to_bits(), "{backend}");
            }
        }
    }
}

macro_rules! backend_suite {
    ($name:ident, $backend:expr) => {
        mod $name {
            use super::*;

            #[test]
            fn out_of_order_tag_parking() {
                suite::out_of_order_tag_parking($backend);
            }

            #[test]
            fn large_and_empty_payloads() {
                suite::large_and_empty_payloads($backend);
            }

            #[test]
            fn dead_peer_recv_errors() {
                suite::dead_peer_recv_errors($backend);
            }

            #[test]
            fn dead_peer_send_errors() {
                suite::dead_peer_send_errors($backend);
            }

            #[test]
            fn in_flight_messages_survive_peer_death() {
                suite::in_flight_messages_survive_peer_death($backend);
            }

            #[test]
            fn allreduce_bit_identical_to_channel() {
                suite::allreduce_bit_identical_to_channel($backend);
            }

            #[test]
            fn zero1_pipeline_bit_identical_to_channel() {
                suite::zero1_pipeline_bit_identical_to_channel($backend);
            }

            #[test]
            fn wire_accounting_matches_alpha_beta_model() {
                suite::wire_accounting_matches_alpha_beta_model($backend);
            }

            #[test]
            fn bucketed_matches_monolithic() {
                suite::bucketed_matches_monolithic($backend);
            }

            #[test]
            fn nonblocking_ops_roundtrip() {
                suite::nonblocking_ops_roundtrip($backend);
            }

            #[test]
            fn engine_concurrent_buckets_bit_identical() {
                suite::engine_concurrent_buckets_bit_identical($backend);
            }

            #[test]
            fn engine_zero1_pipeline_bit_identical() {
                suite::engine_zero1_pipeline_bit_identical($backend);
            }

            #[test]
            fn engine_dead_peer_mid_collective_errors() {
                suite::engine_dead_peer_mid_collective_errors($backend);
            }
        }
    };
}

backend_suite!(channel, Backend::Channel);
backend_suite!(shm, Backend::Shm);
backend_suite!(tcp, Backend::Tcp);
