//! Transport conformance suite: every backend behind the
//! `training.transport` knob must honor the same contract — selective
//! receive with out-of-order tag parking, payloads of any size,
//! graceful dead-peer errors, identical byte accounting, and (the one
//! that matters for training) bit-identical collective results against
//! the channel reference across worlds {2, 4, 8}.
//!
//! Structure: each check is a function over a [`Backend`]; the
//! `backend_suite!` macro stamps it out as `channel::*`, `shm::*` and
//! `tcp::*` tests, so `cargo test --test integration_transport tcp::`
//! runs one backend's suite in isolation (what `verify.sh` does).

use txgain::collectives::{all_gather, allreduce, bucketed_all_gather,
                          bucketed_allreduce, bucketed_reduce_scatter,
                          reduce_scatter, shard_spans, Algorithm,
                          AnyTransport, Backend, BucketPlan,
                          CollectiveKind, CommEngine, PendingBucket,
                          Topology, Transport, TransportStats,
                          WireCodec};

/// Deterministic integer-valued inputs: sums over ≤8 ranks are exact
/// in f32, so bit-identity across backends/algorithms is well-defined.
fn inputs(world: usize, len: usize) -> Vec<Vec<f32>> {
    (0..world)
        .map(|r| {
            (0..len)
                .map(|i| ((r * 17 + i * 5) % 41) as f32 - 20.0)
                .collect()
        })
        .collect()
}

/// Run `op` on every rank of a fresh `backend` world; returns each
/// rank's buffer and transport stats.
fn run_world(
    backend: Backend,
    bufs: Vec<Vec<f32>>,
    op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>),
) -> Vec<(Vec<f32>, TransportStats)> {
    let world = bufs.len();
    std::thread::scope(|s| {
        backend
            .world(world)
            .unwrap()
            .into_iter()
            .zip(bufs)
            .enumerate()
            .map(|(rank, (mut c, mut buf))| {
                s.spawn(move || {
                    op(rank, world, &mut c, &mut buf);
                    (buf, c.stats())
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    })
}

mod suite {
    use super::*;

    pub fn out_of_order_tag_parking(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.send_slice(1, 1, &[1.0]).unwrap();
        c0.send_slice(1, 2, &[2.0]).unwrap();
        c0.send_slice(1, 1, &[3.0]).unwrap();
        // claiming tag 2 first must park (not drop or reorder) tag 1
        assert_eq!(c1.recv(0, 2).unwrap(), vec![2.0]);
        assert_eq!(c1.recv(0, 1).unwrap(), vec![1.0]);
        assert_eq!(c1.recv(0, 1).unwrap(), vec![3.0]);
    }

    pub fn large_and_empty_payloads(backend: Backend) {
        // 300k f32 = 1.2 MB: spans many TCP frames and far exceeds a
        // loopback socket buffer, so the sender genuinely streams
        let n = 300_000usize;
        let big: Vec<f32> = (0..n).map(|i| (i % 1013) as f32).collect();
        let expect = big.clone();
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        std::thread::scope(|s| {
            s.spawn(move || {
                c0.send_slice(1, 7, &big).unwrap();
                c0.send_slice(1, 8, &[]).unwrap();
            });
            s.spawn(move || {
                assert_eq!(c1.recv(0, 7).unwrap(), expect, "{backend}");
                assert!(c1.recv(0, 8).unwrap().is_empty(),
                        "{backend}: empty payload mangled");
            });
        });
    }

    pub fn dead_peer_recv_errors(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        drop(c0);
        assert!(c1.recv(0, 0).is_err(),
                "{backend}: recv from dead peer hung or succeeded");
    }

    pub fn dead_peer_send_errors(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        drop(c1);
        // buffered backends may absorb a bounded number of sends; the
        // error must surface within the in-flight window (plus, for
        // tcp, the kernel's RST round-trip)
        let mut failed = false;
        for _ in 0..200 {
            if c0.send_slice(1, 0, &[1.0; 64]).is_err() {
                failed = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(failed, "{backend}: send to dead peer never errored");
    }

    pub fn in_flight_messages_survive_peer_death(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.send_slice(1, 4, &[5.0, 6.0]).unwrap();
        drop(c0);
        assert_eq!(c1.recv(0, 4).unwrap(), vec![5.0, 6.0],
                   "{backend}: in-flight message lost with its sender");
        assert!(c1.recv(0, 4).is_err());
    }

    pub fn allreduce_bit_identical_to_channel(backend: Backend) {
        for world in [2usize, 4, 8] {
            for len in [13usize, 257] {
                for algo in [Algorithm::Ring, Algorithm::Tree] {
                    let op: fn(usize, usize, &mut AnyTransport,
                               &mut Vec<f32>) = match algo {
                        Algorithm::Ring => |_, _, c, buf| {
                            allreduce(Algorithm::Ring, c, buf).unwrap()
                        },
                        Algorithm::Tree => |_, _, c, buf| {
                            allreduce(Algorithm::Tree, c, buf).unwrap()
                        },
                        // needs a topology-bearing transport; its
                        // bit-identity rows live in the `hier` module
                        Algorithm::Hierarchical => continue,
                    };
                    let got =
                        run_world(backend, inputs(world, len), op);
                    let want =
                        run_world(Backend::Channel, inputs(world, len),
                                  op);
                    for (r, ((g, gs), (w, ws))) in
                        got.iter().zip(&want).enumerate()
                    {
                        for (a, b) in g.iter().zip(w) {
                            assert_eq!(
                                a.to_bits(), b.to_bits(),
                                "{backend} {algo} world={world} \
                                 len={len} rank={r}: {a} != {b}");
                        }
                        // identical traffic accounting too
                        assert_eq!(gs, ws,
                                   "{backend} {algo} world={world} \
                                    len={len} rank={r}: stats differ");
                    }
                }
            }
        }
    }

    pub fn zero1_pipeline_bit_identical_to_channel(backend: Backend) {
        // the ZeRO-1 step skeleton: bucketed RS → nonlinear shard
        // update → bucketed AG. (Full AdamW equivalence vs the
        // replicated optimizer is proven over the channel backend in
        // integration_zero; here we prove the transport cannot change
        // the result.)
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |rank, world, c, buf| {
                let plan = BucketPlan::from_elems(buf.len(), 29);
                bucketed_reduce_scatter(Algorithm::Ring, c, buf, &plan)
                    .unwrap();
                for &(a, b) in &plan.rank_ranges(rank, world) {
                    for x in &mut buf[a..b] {
                        // nonlinear, order-sensitive "optimizer step"
                        *x = (*x * 0.5 + 1.0) / (x.abs() + 2.0);
                    }
                }
                bucketed_all_gather(Algorithm::Ring, c, buf, &plan)
                    .unwrap();
            };
        for world in [2usize, 4, 8] {
            let len = 103usize; // uneven vs every bucket/shard boundary
            let got = run_world(backend, inputs(world, len), op);
            let want =
                run_world(Backend::Channel, inputs(world, len), op);
            for (r, ((g, _), (w, _))) in
                got.iter().zip(&want).enumerate()
            {
                for (a, b) in g.iter().zip(w) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{backend} world={world} rank={r}: \
                                {a} != {b}");
                }
                // replicas agree with each other (the DDP invariant)
                assert_eq!(g, &got[0].0);
            }
        }
    }

    pub fn wire_accounting_matches_alpha_beta_model(backend: Backend) {
        // measured wire bytes for a flat ring all-reduce must equal
        // the α-β model's 2(R-1)/R formula at the codec's width — the
        // default codec is f32, so the wire carries the buffer's own
        // 4 B/elem (the per-codec widths are covered in `codec_axis`)
        let world = 4usize;
        let len = 400usize; // divisible by world: exact formula
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Ring, c, buf).unwrap();
            };
        let out = run_world(backend, inputs(world, len), op);
        let elems = (2 * (world - 1) * (len / world)) as u64;
        for (r, (_, stats)) in out.iter().enumerate() {
            assert_eq!(stats.wire_bytes_sent, elems * 4,
                       "{backend} rank={r}: wire bytes");
            assert_eq!(stats.buffer_bytes_sent, elems * 4,
                       "{backend} rank={r}: buffer bytes");
            assert_eq!(stats.wire_bytes_recv, elems * 4,
                       "{backend} rank={r}: ring symmetry broken");
            assert_eq!(stats.msgs_sent, 2 * (world as u64 - 1));
        }
    }

    // ---- async conformance: the nonblocking face + the comm engine.

    pub fn nonblocking_ops_roundtrip(backend: Backend) {
        let mut comms = backend.world(2).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        // empty wire: try_recv reports nothing without blocking
        assert!(c1.try_recv(0, 5).unwrap().is_none(), "{backend}");
        assert!(c0.try_send(1, 5, &[1.5, -2.0]).unwrap(), "{backend}");
        // poll until delivered (thread-backed backends need a moment)
        let mut got = None;
        for _ in 0..10_000 {
            if let Some(v) = c1.try_recv(0, 5).unwrap() {
                got = Some(v);
                break;
            }
            std::thread::yield_now();
        }
        assert_eq!(got, Some(vec![1.5, -2.0]), "{backend}");
        // tag parking holds for the nonblocking face too
        c0.send_slice(1, 1, &[1.0]).unwrap();
        c0.send_slice(1, 2, &[2.0]).unwrap();
        let mut two = None;
        for _ in 0..10_000 {
            if let Some(v) = c1.try_recv(0, 2).unwrap() {
                two = Some(v);
                break;
            }
            std::thread::yield_now();
        }
        assert_eq!(two, Some(vec![2.0]), "{backend}");
        assert_eq!(c1.recv(0, 1).unwrap(), vec![1.0], "{backend}");
        // sustained one-sided sending hits backpressure (Ok(false))
        // within a bounded number of attempts on every backend
        let payload = vec![1.0f32; 300_000];
        let mut accepted = 0usize;
        let mut saw_full = false;
        for _ in 0..64 {
            if c0.try_send(1, 9, &payload).unwrap() {
                accepted += 1;
            } else {
                saw_full = true;
                break;
            }
        }
        assert!(saw_full,
                "{backend}: try_send never reported backpressure \
                 ({accepted} accepted)");
        // everything accepted is still delivered, in order
        for _ in 0..accepted {
            assert_eq!(c1.recv(0, 9).unwrap().len(), 300_000,
                       "{backend}");
        }
    }

    pub fn engine_concurrent_buckets_bit_identical(backend: Backend) {
        // N concurrent outstanding buckets through the comm engine
        // complete bit-identical to the blocking bucketed path across
        // worlds {2, 4, 8} — the tentpole equivalence. The plan has an
        // uneven (smaller) first bucket, so the size-aware partition
        // is conformance-tested on every wire too.
        let len = 103usize;
        let plan_of =
            |n: usize| BucketPlan::from_elems_with_first(n, 23, 7);
        let blocking: fn(usize, usize, &mut AnyTransport,
                         &mut Vec<f32>) = |_, _, c, buf| {
            let plan = BucketPlan::from_elems_with_first(buf.len(), 23,
                                                         7);
            bucketed_allreduce(Algorithm::Ring, c, buf, &plan).unwrap();
        };
        for world in [2usize, 4, 8] {
            let want =
                run_world(Backend::Channel, inputs(world, len),
                          blocking);
            let plan = plan_of(len);
            let got: Vec<Vec<f32>> = std::thread::scope(|s| {
                backend
                    .world(world)
                    .unwrap()
                    .into_iter()
                    .zip(inputs(world, len))
                    .map(|(c, mut buf)| {
                        let plan = plan.clone();
                        s.spawn(move || {
                            let mut eng = CommEngine::new(c);
                            // every bucket in flight at once
                            let pend: Vec<(usize, PendingBucket)> =
                                plan.ready_order()
                                    .map(|i| {
                                        let (a, b) = plan.span(i);
                                        (i, eng.launch_bucket(
                                            Algorithm::Ring,
                                            CollectiveKind::Allreduce,
                                            buf[a..b].to_vec())
                                            .unwrap())
                                    })
                                    .collect();
                            for (i, p) in pend {
                                let (a, b) = plan.span(i);
                                let got = eng.wait(p).unwrap();
                                buf[a..b].copy_from_slice(&got);
                                eng.recycle(got);
                            }
                            buf
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            for (r, (g, (w, _))) in got.iter().zip(&want).enumerate() {
                for (a, b) in g.iter().zip(w) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{backend} world={world} rank={r}: \
                                {a} != {b}");
                }
                assert_eq!(g, &got[0], "replicas diverged");
            }
        }
    }

    pub fn engine_zero1_pipeline_bit_identical(backend: Backend) {
        // the engine-driven ZeRO-1 skeleton (concurrent RS buckets →
        // nonlinear shard update as each lands → concurrent AG
        // buckets) against the blocking reference — the exact overlap
        // schedule the trainer runs under `comm_engine`
        let len = 103usize;
        let blocking: fn(usize, usize, &mut AnyTransport,
                         &mut Vec<f32>) = |rank, world, c, buf| {
            let plan = BucketPlan::from_elems(buf.len(), 29);
            bucketed_reduce_scatter(Algorithm::Ring, c, buf, &plan)
                .unwrap();
            for &(a, b) in &plan.rank_ranges(rank, world) {
                for x in &mut buf[a..b] {
                    *x = (*x * 0.5 + 1.0) / (x.abs() + 2.0);
                }
            }
            bucketed_all_gather(Algorithm::Ring, c, buf, &plan).unwrap();
        };
        for world in [2usize, 4, 8] {
            let want =
                run_world(Backend::Channel, inputs(world, len),
                          blocking);
            let got: Vec<Vec<f32>> = std::thread::scope(|s| {
                backend
                    .world(world)
                    .unwrap()
                    .into_iter()
                    .zip(inputs(world, len))
                    .enumerate()
                    .map(|(rank, (c, mut buf))| {
                        s.spawn(move || {
                            let plan =
                                BucketPlan::from_elems(buf.len(), 29);
                            let mut eng = CommEngine::new(c);
                            let pend: Vec<(usize, PendingBucket)> =
                                plan.ready_order()
                                    .map(|i| {
                                        let (a, b) = plan.span(i);
                                        (i, eng.launch_bucket(
                                            Algorithm::Ring,
                                            CollectiveKind::ReduceScatter,
                                            buf[a..b].to_vec())
                                            .unwrap())
                                    })
                                    .collect();
                            // RS(k) wait → shard update → AG(k)
                            // launch, while RS(k+1..) is in flight
                            let mut ag = Vec::new();
                            for (i, p) in pend {
                                let (a, b) = plan.span(i);
                                let mut got = eng.wait(p).unwrap();
                                let (sa, sb) =
                                    plan.shard_span(i, rank, world);
                                for x in &mut got[sa - a..sb - a] {
                                    *x = (*x * 0.5 + 1.0)
                                        / (x.abs() + 2.0);
                                }
                                ag.push((i, eng.launch_bucket(
                                    Algorithm::Ring,
                                    CollectiveKind::AllGather, got)
                                    .unwrap()));
                            }
                            for (i, p) in ag {
                                let (a, b) = plan.span(i);
                                let got = eng.wait(p).unwrap();
                                buf[a..b].copy_from_slice(&got);
                                eng.recycle(got);
                            }
                            buf
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            for (r, (g, (w, _))) in got.iter().zip(&want).enumerate() {
                for (a, b) in g.iter().zip(w) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{backend} world={world} rank={r}: \
                                {a} != {b}");
                }
            }
        }
    }

    pub fn engine_dead_peer_mid_collective_errors(backend: Backend) {
        // a rank that dies with buckets in flight must surface as an
        // error on every surviving rank's wait — never a hang. (The
        // surviving engines tear down and cascade, so *all* waits
        // resolve.)
        let mut comms = backend.world(3).unwrap();
        let c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        std::thread::scope(|s| {
            s.spawn(move || drop(c2)); // rank 2 never participates
            for c in [c0, c1] {
                s.spawn(move || {
                    let mut eng = CommEngine::new(c);
                    let pend: Vec<PendingBucket> = (0..3)
                        .map(|k| {
                            eng.launch_bucket(
                                Algorithm::Ring,
                                CollectiveKind::Allreduce,
                                vec![k as f32; 32])
                                .unwrap()
                        })
                        .collect();
                    let mut failures = 0;
                    for p in pend {
                        if eng.wait(p).is_err() {
                            failures += 1;
                        }
                    }
                    assert!(failures > 0,
                            "{backend}: no in-flight bucket reported \
                             the dead peer");
                });
            }
        });
    }

    pub fn bucketed_matches_monolithic(backend: Backend) {
        // bucketing must not change the result on any transport
        let world = 4usize;
        let len = 230usize;
        let mono: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Ring, c, buf).unwrap();
            };
        let bucketed: fn(usize, usize, &mut AnyTransport,
                         &mut Vec<f32>) = |_, _, c, buf| {
            let plan = BucketPlan::from_elems(buf.len(), 37);
            bucketed_allreduce(Algorithm::Ring, c, buf, &plan).unwrap();
        };
        let a = run_world(backend, inputs(world, len), mono);
        let b = run_world(backend, inputs(world, len), bucketed);
        for ((x, _), (y, _)) in a.iter().zip(&b) {
            for (p, q) in x.iter().zip(y) {
                assert_eq!(p.to_bits(), q.to_bits(), "{backend}");
            }
        }
    }
}

macro_rules! backend_suite {
    ($name:ident, $backend:expr) => {
        mod $name {
            use super::*;

            #[test]
            fn out_of_order_tag_parking() {
                suite::out_of_order_tag_parking($backend);
            }

            #[test]
            fn large_and_empty_payloads() {
                suite::large_and_empty_payloads($backend);
            }

            #[test]
            fn dead_peer_recv_errors() {
                suite::dead_peer_recv_errors($backend);
            }

            #[test]
            fn dead_peer_send_errors() {
                suite::dead_peer_send_errors($backend);
            }

            #[test]
            fn in_flight_messages_survive_peer_death() {
                suite::in_flight_messages_survive_peer_death($backend);
            }

            #[test]
            fn allreduce_bit_identical_to_channel() {
                suite::allreduce_bit_identical_to_channel($backend);
            }

            #[test]
            fn zero1_pipeline_bit_identical_to_channel() {
                suite::zero1_pipeline_bit_identical_to_channel($backend);
            }

            #[test]
            fn wire_accounting_matches_alpha_beta_model() {
                suite::wire_accounting_matches_alpha_beta_model($backend);
            }

            #[test]
            fn bucketed_matches_monolithic() {
                suite::bucketed_matches_monolithic($backend);
            }

            #[test]
            fn nonblocking_ops_roundtrip() {
                suite::nonblocking_ops_roundtrip($backend);
            }

            #[test]
            fn engine_concurrent_buckets_bit_identical() {
                suite::engine_concurrent_buckets_bit_identical($backend);
            }

            #[test]
            fn engine_zero1_pipeline_bit_identical() {
                suite::engine_zero1_pipeline_bit_identical($backend);
            }

            #[test]
            fn engine_dead_peer_mid_collective_errors() {
                suite::engine_dead_peer_mid_collective_errors($backend);
            }
        }
    };
}

backend_suite!(channel, Backend::Channel);
backend_suite!(shm, Backend::Shm);
backend_suite!(tcp, Backend::Tcp);

/// The hierarchical rows: the two-tier transport + `Algorithm::
/// Hierarchical` against the flat channel ring, on even and uneven
/// groupings. Not stamped from `backend_suite!` — the flat rows'
/// stats-equality-vs-channel assertion cannot hold here (the hier
/// transport fills the per-tier counters the flat backends leave zero),
/// and the collectives need a `Topology` the macro has no slot for.
mod hier {
    use super::*;
    use txgain::collectives::hier::tier_wire_elems;

    /// Even and uneven groupings per world — the grouping sweep every
    /// row below runs over.
    fn topologies(world: usize) -> Vec<Topology> {
        let specs: &[&str] = match world {
            4 => &["2,2", "3,1"],
            8 => &["4,4", "4,3,1"],
            _ => panic!("no hier grouping sweep for world {world}"),
        };
        specs.iter().map(|s| s.parse().unwrap()).collect()
    }

    /// Run `op` on every rank of a fresh hier world over `topo`.
    fn run_hier(
        topo: &Topology,
        bufs: Vec<Vec<f32>>,
        op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>),
    ) -> Vec<(Vec<f32>, TransportStats)> {
        let world = bufs.len();
        assert_eq!(world, topo.world());
        std::thread::scope(|s| {
            Backend::Hier
                .world_with(world, Some(topo), WireCodec::F32)
                .unwrap()
                .into_iter()
                .zip(bufs)
                .enumerate()
                .map(|(rank, (mut c, mut buf))| {
                    s.spawn(move || {
                        op(rank, world, &mut c, &mut buf);
                        (buf, c.stats())
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        })
    }

    #[test]
    fn allreduce_bit_identical_to_flat_ring() {
        // the inputs are integer-valued, so every sum is exact in f32
        // and the hierarchical association must reproduce the flat
        // ring's bits exactly — on even and uneven groupings alike
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Hierarchical, c, buf).unwrap()
            };
        let flat: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Ring, c, buf).unwrap()
            };
        for world in [4usize, 8] {
            for topo in topologies(world) {
                for len in [13usize, 257] {
                    let got = run_hier(&topo, inputs(world, len), op);
                    let want = run_world(Backend::Channel,
                                         inputs(world, len), flat);
                    for (r, ((g, _), (w, _))) in
                        got.iter().zip(&want).enumerate()
                    {
                        for (a, b) in g.iter().zip(w) {
                            assert_eq!(a.to_bits(), b.to_bits(),
                                       "topo={topo} len={len} \
                                        rank={r}: {a} != {b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_owns_flat_ring_bits() {
        // after hier RS, rank r's shard_spans span must hold exactly
        // the flat ring's bits — the ownership contract ZeRO-1 uses
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                reduce_scatter(Algorithm::Hierarchical, c, buf).unwrap()
            };
        let flat: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                reduce_scatter(Algorithm::Ring, c, buf).unwrap()
            };
        for world in [4usize, 8] {
            for topo in topologies(world) {
                for len in [13usize, 257] {
                    let got = run_hier(&topo, inputs(world, len), op);
                    let want = run_world(Backend::Channel,
                                         inputs(world, len), flat);
                    let spans = shard_spans(len, world);
                    for (r, ((g, _), (w, _))) in
                        got.iter().zip(&want).enumerate()
                    {
                        let (a, b) = spans[r];
                        for (x, y) in g[a..b].iter().zip(&w[a..b]) {
                            assert_eq!(x.to_bits(), y.to_bits(),
                                       "topo={topo} len={len} \
                                        rank={r}: {x} != {y}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn all_gather_distributes_owned_spans_bit_for_bit() {
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                all_gather(Algorithm::Hierarchical, c, buf).unwrap()
            };
        let flat: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                all_gather(Algorithm::Ring, c, buf).unwrap()
            };
        for world in [4usize, 8] {
            for topo in topologies(world) {
                for len in [13usize, 257] {
                    // rank r starts with only its own span
                    // authoritative; -1 elsewhere must be overwritten
                    let want_vec: Vec<f32> = (0..len)
                        .map(|i| ((i * 3) % 17) as f32 - 8.0)
                        .collect();
                    let spans = shard_spans(len, world);
                    let seed = |_: ()| -> Vec<Vec<f32>> {
                        (0..world)
                            .map(|r| {
                                let mut buf = vec![-1.0f32; len];
                                let (a, b) = spans[r];
                                buf[a..b]
                                    .copy_from_slice(&want_vec[a..b]);
                                buf
                            })
                            .collect()
                    };
                    let got = run_hier(&topo, seed(()), op);
                    let want =
                        run_world(Backend::Channel, seed(()), flat);
                    for (r, ((g, _), (w, _))) in
                        got.iter().zip(&want).enumerate()
                    {
                        for (x, y) in g.iter().zip(w) {
                            assert_eq!(x.to_bits(), y.to_bits(),
                                       "topo={topo} len={len} \
                                        rank={r}: {x} != {y}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn per_tier_wire_bytes_match_the_schedule_formula() {
        // measured per-tier wire traffic must equal the replayed
        // schedule's element counts × 4 B (the default f32 codec) —
        // the check the cost model's hierarchical pricing rests on;
        // the reduced-width variants are covered in `codec_axis`
        for world in [4usize, 8] {
            for topo in topologies(world) {
                for (kind, op) in [
                    (CollectiveKind::Allreduce,
                     (|_, _, c: &mut AnyTransport, buf: &mut Vec<f32>| {
                         allreduce(Algorithm::Hierarchical, c, buf)
                             .unwrap()
                     }) as fn(usize, usize, &mut AnyTransport,
                              &mut Vec<f32>)),
                    (CollectiveKind::ReduceScatter,
                     |_, _, c: &mut AnyTransport, buf: &mut Vec<f32>| {
                         reduce_scatter(Algorithm::Hierarchical, c, buf)
                             .unwrap()
                     }),
                    (CollectiveKind::AllGather,
                     |_, _, c: &mut AnyTransport, buf: &mut Vec<f32>| {
                         all_gather(Algorithm::Hierarchical, c, buf)
                             .unwrap()
                     }),
                ] {
                    let len = 256usize;
                    let out = run_hier(&topo, inputs(world, len), op);
                    let (intra, inter) =
                        tier_wire_elems(&topo, len, kind);
                    let intra_sent: u64 = out.iter()
                        .map(|(_, s)| s.intra_wire_bytes_sent)
                        .sum();
                    let inter_sent: u64 = out.iter()
                        .map(|(_, s)| s.inter_wire_bytes_sent)
                        .sum();
                    let inter_recv: u64 = out.iter()
                        .map(|(_, s)| s.inter_wire_bytes_recv)
                        .sum();
                    assert_eq!(intra_sent, intra * 4,
                               "topo={topo} {kind:?}: intra tier");
                    assert_eq!(inter_sent, inter * 4,
                               "topo={topo} {kind:?}: inter tier");
                    // every slow-tier byte sent is received
                    assert_eq!(inter_recv, inter * 4,
                               "topo={topo} {kind:?}: inter symmetry");
                    // and the tier split exhausts the totals
                    for (r, (_, s)) in out.iter().enumerate() {
                        assert_eq!(s.wire_bytes_sent,
                                   s.intra_wire_bytes_sent
                                       + s.inter_wire_bytes_sent,
                                   "topo={topo} {kind:?} rank={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn flat_ring_on_hier_transport_splits_tiers() {
        // the hier transport runs flat collectives unchanged (that is
        // what makes the flat-vs-hier benchmark apples-to-apples);
        // routing only decides which tier carries each hop
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Ring, c, buf).unwrap()
            };
        let topo: Topology = "2,2".parse().unwrap();
        let len = 256usize;
        let got = run_hier(&topo, inputs(4, len), op);
        let want = run_world(Backend::Channel, inputs(4, len), op);
        let mut intra_total = 0u64;
        let mut inter_total = 0u64;
        for (r, ((g, gs), (w, ws))) in
            got.iter().zip(&want).enumerate()
        {
            for (a, b) in g.iter().zip(w) {
                assert_eq!(a.to_bits(), b.to_bits(), "rank={r}");
            }
            // same totals as any flat backend, split across tiers
            assert_eq!(gs.wire_bytes_sent, ws.wire_bytes_sent);
            assert_eq!(gs.wire_bytes_sent,
                       gs.intra_wire_bytes_sent
                           + gs.inter_wire_bytes_sent);
            intra_total += gs.intra_wire_bytes_sent;
            inter_total += gs.inter_wire_bytes_sent;
        }
        // on 2+2 the flat ring crosses the group boundary twice per
        // lap: both tiers must carry real traffic
        assert!(intra_total > 0 && inter_total > 0,
                "intra={intra_total} inter={inter_total}");
    }

    #[test]
    fn dead_peer_errors_on_both_tiers() {
        let topo: Topology = "2,2".parse().unwrap();
        // intra tier: rank 1 (same group as 0) dies
        let mut comms = Backend::Hier.world_with(4, Some(&topo), WireCodec::F32).unwrap();
        let c3 = comms.pop().unwrap();
        let c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        drop(c1);
        assert!(c0.recv(1, 0).is_err(),
                "intra-tier recv from dead peer hung or succeeded");
        drop((c2, c3));

        // inter tier: rank 2 (other group's leader) dies
        let mut comms = Backend::Hier.world_with(4, Some(&topo), WireCodec::F32).unwrap();
        let c3 = comms.pop().unwrap();
        let c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        drop(c2);
        assert!(c0.recv(2, 0).is_err(),
                "inter-tier recv from dead peer hung or succeeded");
        drop((c1, c3));
    }

    #[test]
    fn engine_concurrent_hier_buckets_bit_identical() {
        // concurrent hierarchical buckets through the comm engine vs
        // the flat channel ring, blocking and bucketed — the engine's
        // resumable state machines must reproduce the same exact sums
        let len = 103usize;
        let blocking: fn(usize, usize, &mut AnyTransport,
                         &mut Vec<f32>) = |_, _, c, buf| {
            let plan = BucketPlan::from_elems_with_first(buf.len(), 23,
                                                         7);
            bucketed_allreduce(Algorithm::Ring, c, buf, &plan).unwrap();
        };
        for world in [4usize, 8] {
            for topo in topologies(world) {
                let want = run_world(Backend::Channel,
                                     inputs(world, len), blocking);
                let plan =
                    BucketPlan::from_elems_with_first(len, 23, 7);
                let got: Vec<Vec<f32>> = std::thread::scope(|s| {
                    Backend::Hier
                        .world_with(world, Some(&topo), WireCodec::F32)
                        .unwrap()
                        .into_iter()
                        .zip(inputs(world, len))
                        .map(|(c, mut buf)| {
                            let plan = plan.clone();
                            s.spawn(move || {
                                let mut eng = CommEngine::new(c);
                                let pend: Vec<(usize, PendingBucket)> =
                                    plan.ready_order()
                                        .map(|i| {
                                            let (a, b) = plan.span(i);
                                            (i, eng.launch_bucket(
                                                Algorithm::Hierarchical,
                                                CollectiveKind::Allreduce,
                                                buf[a..b].to_vec())
                                                .unwrap())
                                        })
                                        .collect();
                                for (i, p) in pend {
                                    let (a, b) = plan.span(i);
                                    let got = eng.wait(p).unwrap();
                                    buf[a..b].copy_from_slice(&got);
                                    eng.recycle(got);
                                }
                                buf
                            })
                        })
                        .collect::<Vec<_>>()
                        .into_iter()
                        .map(|h| h.join().unwrap())
                        .collect()
                });
                for (r, (g, (w, _))) in
                    got.iter().zip(&want).enumerate()
                {
                    for (a, b) in g.iter().zip(w) {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "topo={topo} rank={r}: {a} != {b}");
                    }
                    assert_eq!(g, &got[0], "replicas diverged");
                }
            }
        }
    }

    #[test]
    fn hier_per_tier_bytes_follow_the_codec_width() {
        // the per-tier counters are measured through the same codec
        // boundary as the totals: under bf16 every tier's wire bytes
        // are exactly 2 B/elem of the replayed schedule's counts
        let topo: Topology = "2,2".parse().unwrap();
        let len = 256usize;
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Hierarchical, c, buf).unwrap()
            };
        let world = 4usize;
        let out: Vec<(Vec<f32>, TransportStats)> =
            std::thread::scope(|s| {
                Backend::Hier
                    .world_with(world, Some(&topo), WireCodec::Bf16)
                    .unwrap()
                    .into_iter()
                    .zip(inputs(world, len))
                    .enumerate()
                    .map(|(rank, (mut c, mut buf))| {
                        s.spawn(move || {
                            op(rank, world, &mut c, &mut buf);
                            (buf, c.stats())
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
        let (intra, inter) =
            tier_wire_elems(&topo, len, CollectiveKind::Allreduce);
        let intra_sent: u64 =
            out.iter().map(|(_, s)| s.intra_wire_bytes_sent).sum();
        let inter_sent: u64 =
            out.iter().map(|(_, s)| s.inter_wire_bytes_sent).sum();
        assert_eq!(intra_sent, intra * 2, "bf16 intra tier");
        assert_eq!(inter_sent, inter * 2, "bf16 inter tier");
        for (r, (_, s)) in out.iter().enumerate() {
            assert_eq!(s.wire_bytes_sent,
                       s.intra_wire_bytes_sent
                           + s.inter_wire_bytes_sent,
                       "rank={r}: tier split must exhaust the total");
        }
    }

    #[test]
    fn engine_hier_zero1_pipeline_bit_identical() {
        // the engine-driven ZeRO-1 skeleton on hierarchical
        // collectives (concurrent hier RS → nonlinear shard update →
        // concurrent hier AG) against the flat channel-ring blocking
        // reference. The RS sums are exact integers, the update is
        // applied to identical bits, and AG moves bits verbatim — so
        // the whole pipeline must agree exactly.
        let len = 103usize;
        let blocking: fn(usize, usize, &mut AnyTransport,
                         &mut Vec<f32>) = |rank, world, c, buf| {
            let plan = BucketPlan::from_elems(buf.len(), 29);
            bucketed_reduce_scatter(Algorithm::Ring, c, buf, &plan)
                .unwrap();
            for &(a, b) in &plan.rank_ranges(rank, world) {
                for x in &mut buf[a..b] {
                    *x = (*x * 0.5 + 1.0) / (x.abs() + 2.0);
                }
            }
            bucketed_all_gather(Algorithm::Ring, c, buf, &plan).unwrap();
        };
        for world in [4usize, 8] {
            for topo in topologies(world) {
                let want = run_world(Backend::Channel,
                                     inputs(world, len), blocking);
                let got: Vec<Vec<f32>> = std::thread::scope(|s| {
                    Backend::Hier
                        .world_with(world, Some(&topo), WireCodec::F32)
                        .unwrap()
                        .into_iter()
                        .zip(inputs(world, len))
                        .enumerate()
                        .map(|(rank, (c, mut buf))| {
                            s.spawn(move || {
                                let plan =
                                    BucketPlan::from_elems(buf.len(),
                                                           29);
                                let mut eng = CommEngine::new(c);
                                let pend: Vec<(usize, PendingBucket)> =
                                    plan.ready_order()
                                        .map(|i| {
                                            let (a, b) = plan.span(i);
                                            (i, eng.launch_bucket(
                                                Algorithm::Hierarchical,
                                                CollectiveKind::ReduceScatter,
                                                buf[a..b].to_vec())
                                                .unwrap())
                                        })
                                        .collect();
                                let mut ag = Vec::new();
                                for (i, p) in pend {
                                    let (a, b) = plan.span(i);
                                    let mut got = eng.wait(p).unwrap();
                                    let (sa, sb) =
                                        plan.shard_span(i, rank, world);
                                    for x in &mut got[sa - a..sb - a] {
                                        *x = (*x * 0.5 + 1.0)
                                            / (x.abs() + 2.0);
                                    }
                                    ag.push((i, eng.launch_bucket(
                                        Algorithm::Hierarchical,
                                        CollectiveKind::AllGather, got)
                                        .unwrap()));
                                }
                                for (i, p) in ag {
                                    let (a, b) = plan.span(i);
                                    let got = eng.wait(p).unwrap();
                                    buf[a..b].copy_from_slice(&got);
                                    eng.recycle(got);
                                }
                                buf
                            })
                        })
                        .collect::<Vec<_>>()
                        .into_iter()
                        .map(|h| h.join().unwrap())
                        .collect()
                });
                for (r, (g, (w, _))) in
                    got.iter().zip(&want).enumerate()
                {
                    for (a, b) in g.iter().zip(w) {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "topo={topo} rank={r}: {a} != {b}");
                    }
                }
            }
        }
    }
}

/// The wire-codec axis (the reduced-precision tentpole): every codec
/// on every backend must (a) put exactly its advertised bytes on the
/// wire — measured, not modeled — (b) honor its numeric contract:
/// bit-identity for `f32` always and for `bf16` on exact-in-bf16
/// inputs, a provable accumulation bound on everything else, and
/// (c) keep dead-peer errors and the engine/blocking bit-equivalence
/// intact under every encoding.
mod codec_axis {
    use super::*;

    const BACKENDS: [Backend; 3] =
        [Backend::Channel, Backend::Shm, Backend::Tcp];

    /// Fractional inputs that are NOT exact in bf16 or int8, so the
    /// error-bound rows measure real rounding rather than luck.
    fn rough_inputs(world: usize, len: usize) -> Vec<Vec<f32>> {
        (0..world)
            .map(|r| {
                (0..len)
                    .map(|i| {
                        ((r * 31 + i * 7) % 97) as f32 * 0.013 - 0.6
                    })
                    .collect()
            })
            .collect()
    }

    /// Run `op` on every rank of a fresh `backend` world with `codec`
    /// on every wire.
    fn run_codec_world(
        backend: Backend,
        codec: WireCodec,
        bufs: Vec<Vec<f32>>,
        op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>),
    ) -> Vec<(Vec<f32>, TransportStats)> {
        let world = bufs.len();
        std::thread::scope(|s| {
            backend
                .world_with(world, None, codec)
                .unwrap()
                .into_iter()
                .zip(bufs)
                .enumerate()
                .map(|(rank, (mut c, mut buf))| {
                    s.spawn(move || {
                        op(rank, world, &mut c, &mut buf);
                        (buf, c.stats())
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        })
    }

    #[test]
    fn f32_codec_is_bit_identical_to_the_default_wire() {
        // wire_codec = "f32" must be indistinguishable from the
        // pre-codec wire: same bits, same traffic accounting
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Ring, c, buf).unwrap();
            };
        for backend in BACKENDS {
            let got = run_codec_world(backend, WireCodec::F32,
                                      inputs(4, 103), op);
            let want = run_world(backend, inputs(4, 103), op);
            for (r, ((g, gs), (w, ws))) in
                got.iter().zip(&want).enumerate()
            {
                for (a, b) in g.iter().zip(w) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{backend} rank={r}");
                }
                assert_eq!(gs, ws, "{backend} rank={r}: stats differ");
            }
        }
    }

    #[test]
    fn bf16_is_bit_identical_on_exact_inputs() {
        // `inputs` is integer-valued in [-20, 20] and every partial
        // sum over ≤8 ranks stays below 256 — all exact in bf16's
        // 8-bit significand. The bf16 wire must therefore reproduce
        // the f32 run bit for bit, on every backend and algorithm.
        for world in [2usize, 4, 8] {
            for algo in [Algorithm::Ring, Algorithm::Tree] {
                let op: fn(usize, usize, &mut AnyTransport,
                           &mut Vec<f32>) = match algo {
                    Algorithm::Ring => |_, _, c, buf| {
                        allreduce(Algorithm::Ring, c, buf).unwrap()
                    },
                    Algorithm::Tree => |_, _, c, buf| {
                        allreduce(Algorithm::Tree, c, buf).unwrap()
                    },
                    Algorithm::Hierarchical => unreachable!(),
                };
                let want = run_codec_world(Backend::Channel,
                                           WireCodec::F32,
                                           inputs(world, 103), op);
                for backend in BACKENDS {
                    let got = run_codec_world(backend, WireCodec::Bf16,
                                              inputs(world, 103), op);
                    for (r, ((g, _), (w, _))) in
                        got.iter().zip(&want).enumerate()
                    {
                        for (a, b) in g.iter().zip(w) {
                            assert_eq!(a.to_bits(), b.to_bits(),
                                       "{backend} {algo} world={world} \
                                        rank={r}: {a} != {b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn lossy_codecs_stay_within_the_accumulation_bound() {
        // on rough (non-exact) inputs the lossy wire drifts from the
        // f32 result, but provably: every hop rounds a partial sum
        // whose magnitude is ≤ W·max|input|, with ≤ W+2 roundings on
        // any element's path. bf16 rounds at 2^-8 relative; int8 at
        // scale/2 = max/254 absolute per encode (×2 slack on both).
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Ring, c, buf).unwrap();
            };
        for world in [2usize, 4, 8] {
            let len = 103usize;
            let max_in = rough_inputs(world, len)
                .iter()
                .flatten()
                .fold(0f32, |m, x| m.max(x.abs()));
            let want = run_codec_world(Backend::Channel, WireCodec::F32,
                                       rough_inputs(world, len), op);
            for (codec, tol) in [
                (WireCodec::Bf16,
                 (world as f32 + 2.0) * world as f32 * max_in / 128.0),
                (WireCodec::Int8,
                 (world as f32 + 2.0) * world as f32 * max_in / 127.0),
            ] {
                for backend in BACKENDS {
                    let got = run_codec_world(
                        backend, codec, rough_inputs(world, len), op);
                    for (r, ((g, _), (w, _))) in
                        got.iter().zip(&want).enumerate()
                    {
                        for (i, (a, b)) in g.iter().zip(w).enumerate()
                        {
                            assert!(
                                (a - b).abs() <= tol,
                                "{backend} {codec} world={world} \
                                 rank={r} elem={i}: |{a} - {b}| > \
                                 {tol}");
                        }
                    }
                    // bf16 keeps the replica-identity invariant: the
                    // own-span rounding makes every rank hold the
                    // same bits (int8's per-rank residuals give this
                    // up by design — replicas only track each other)
                    if codec == WireCodec::Bf16 {
                        for (r, (g, _)) in got.iter().enumerate() {
                            assert_eq!(g, &got[0].0,
                                       "{backend} world={world} \
                                        rank={r}: bf16 replicas \
                                        diverged");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn bf16_zero1_skeleton_keeps_replicas_identical() {
        // RS → nonlinear shard update (whose outputs are NOT bf16
        // values) → AG: the all-gather's own-span rounding must leave
        // every replica bit-identical anyway — the invariant the
        // trainer's checksum assert rides under bf16
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |rank, world, c, buf| {
                let plan = BucketPlan::from_elems(buf.len(), 29);
                bucketed_reduce_scatter(Algorithm::Ring, c, buf, &plan)
                    .unwrap();
                for &(a, b) in &plan.rank_ranges(rank, world) {
                    for x in &mut buf[a..b] {
                        *x = (*x * 0.5 + 1.0) / (x.abs() + 2.0);
                    }
                }
                bucketed_all_gather(Algorithm::Ring, c, buf, &plan)
                    .unwrap();
            };
        for world in [2usize, 4, 8] {
            for backend in BACKENDS {
                let got = run_codec_world(backend, WireCodec::Bf16,
                                          rough_inputs(world, 103), op);
                for (r, (g, _)) in got.iter().enumerate() {
                    assert_eq!(g, &got[0].0,
                               "{backend} world={world} rank={r}: \
                                replicas diverged");
                }
            }
        }
    }

    #[test]
    fn wire_bytes_follow_the_codec_width() {
        // the acceptance criterion, measured: a ring all-reduce sends
        // 2(R-1) spans of len/R elems per rank, and the counters must
        // equal the codec's exact per-message byte formulas — payload
        // at bytes-per-elem, framing in the overhead counter. bf16's
        // payload is exactly half of f32's.
        let world = 4usize;
        let len = 400usize; // span 100: even and 4-lane aligned
        let op: fn(usize, usize, &mut AnyTransport, &mut Vec<f32>) =
            |_, _, c, buf| {
                allreduce(Algorithm::Ring, c, buf).unwrap();
            };
        let span = len / world;
        let msgs = 2 * (world as u64 - 1);
        for backend in BACKENDS {
            let mut per_codec = Vec::new();
            for codec in WireCodec::ALL {
                let out = run_codec_world(backend, codec,
                                          inputs(world, len), op);
                for (r, (_, s)) in out.iter().enumerate() {
                    assert_eq!(s.wire_bytes_sent,
                               msgs * codec.wire_bytes(span),
                               "{backend} {codec} rank={r}: payload");
                    assert_eq!(s.wire_bytes_recv,
                               msgs * codec.wire_bytes(span),
                               "{backend} {codec} rank={r}: symmetry");
                    assert_eq!(s.wire_overhead_bytes_sent,
                               msgs * codec.overhead_bytes(span),
                               "{backend} {codec} rank={r}: overhead");
                    // the host-side buffer traffic is codec-invariant
                    assert_eq!(s.buffer_bytes_sent,
                               msgs * span as u64 * 4,
                               "{backend} {codec} rank={r}: buffer");
                }
                per_codec.push(out[0].1.wire_bytes_sent);
            }
            // bf16 moves exactly half the f32 payload, int8 a quarter
            assert_eq!(per_codec[1] * 2, per_codec[0], "{backend}");
            assert_eq!(per_codec[2] * 4, per_codec[0], "{backend}");
        }
    }

    #[test]
    fn dead_peer_errors_under_every_codec() {
        // precision must not cost liveness: a dead peer is a typed
        // error under every encoding, on every backend
        for backend in BACKENDS {
            for codec in WireCodec::ALL {
                let mut comms =
                    backend.world_with(2, None, codec).unwrap();
                let mut c1 = comms.pop().unwrap();
                let c0 = comms.pop().unwrap();
                drop(c0);
                assert!(c1.recv(0, 0).is_err(),
                        "{backend} {codec}: recv from dead peer hung \
                         or succeeded");
            }
        }
    }

    #[test]
    fn engine_matches_blocking_under_every_codec() {
        // the comm engine replays the blocking hop schedules and the
        // same own-copy rounding points, so its results must be
        // bit-identical to the blocking path under every codec —
        // including int8, where both paths quantize identical partial
        // sums through fresh residual streams
        let len = 103usize;
        for codec in WireCodec::ALL {
            for world in [2usize, 4] {
                let blocking: fn(usize, usize, &mut AnyTransport,
                                 &mut Vec<f32>) = |_, _, c, buf| {
                    let plan =
                        BucketPlan::from_elems_with_first(buf.len(),
                                                          23, 7);
                    bucketed_allreduce(Algorithm::Ring, c, buf, &plan)
                        .unwrap();
                };
                for backend in BACKENDS {
                    let want = run_codec_world(
                        backend, codec, rough_inputs(world, len),
                        blocking);
                    let plan =
                        BucketPlan::from_elems_with_first(len, 23, 7);
                    let got: Vec<Vec<f32>> = std::thread::scope(|s| {
                        backend
                            .world_with(world, None, codec)
                            .unwrap()
                            .into_iter()
                            .zip(rough_inputs(world, len))
                            .map(|(c, mut buf)| {
                                let plan = plan.clone();
                                s.spawn(move || {
                                    let mut eng = CommEngine::new(c);
                                    let pend: Vec<(usize,
                                                   PendingBucket)> =
                                        plan.ready_order()
                                            .map(|i| {
                                                let (a, b) =
                                                    plan.span(i);
                                                (i, eng.launch_bucket(
                                                    Algorithm::Ring,
                                                    CollectiveKind::Allreduce,
                                                    buf[a..b].to_vec())
                                                    .unwrap())
                                            })
                                            .collect();
                                    for (i, p) in pend {
                                        let (a, b) = plan.span(i);
                                        let got = eng.wait(p).unwrap();
                                        buf[a..b]
                                            .copy_from_slice(&got);
                                        eng.recycle(got);
                                    }
                                    buf
                                })
                            })
                            .collect::<Vec<_>>()
                            .into_iter()
                            .map(|h| h.join().unwrap())
                            .collect()
                    });
                    for (r, (g, (w, _))) in
                        got.iter().zip(&want).enumerate()
                    {
                        for (a, b) in g.iter().zip(w) {
                            assert_eq!(
                                a.to_bits(), b.to_bits(),
                                "{backend} {codec} world={world} \
                                 rank={r}: engine {a} != blocking \
                                 {b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn exempt_control_tags_ride_exact_under_lossy_codecs() {
        // the checksum-verify plane moves u64 bit patterns as f32
        // words; under a lossy codec those tags must still round-trip
        // exactly (0x9200 is in the exempt window)
        for backend in BACKENDS {
            for codec in [WireCodec::Bf16, WireCodec::Int8] {
                let mut comms =
                    backend.world_with(2, None, codec).unwrap();
                let mut c1 = comms.pop().unwrap();
                let mut c0 = comms.pop().unwrap();
                let checksum: u64 = 0xDEAD_BEEF_CAFE_F00D;
                let payload = [f32::from_bits((checksum >> 32) as u32),
                               f32::from_bits(checksum as u32)];
                c0.send_slice(1, 0x9200, &payload).unwrap();
                let got = c1.recv(0, 0x9200).unwrap();
                assert_eq!(got.len(), 2, "{backend} {codec}");
                let back = ((got[0].to_bits() as u64) << 32)
                    | got[1].to_bits() as u64;
                assert_eq!(back, checksum,
                           "{backend} {codec}: exempt tag was \
                            re-encoded");
            }
        }
    }
}
