//! Integration: the data pipeline end to end — corpus → tokenizer →
//! shards → staging → loader → masked batches — on real files.

use std::path::PathBuf;
use std::sync::Arc;

use txgain::config::{DataConfig, StagingPolicy};
use txgain::data::loader::load_dataset;
use txgain::data::{
    preprocess_corpus, special, staging, EpochPlan, LoaderPool, Masker,
};

fn cfg(samples: usize) -> DataConfig {
    DataConfig {
        corpus_samples: samples,
        fn_size_mu: 6.5,
        fn_size_sigma: 0.6,
        tokenizer_vocab: 350,
        mask_prob: 0.15,
        staging: StagingPolicy::LocalCopy,
        loaders_per_gpu: 2,
        prefetch_batches: 2,
        samples_per_shard: 100,
        cache_mb: 8.0,
        shuffle_window: 64,
        prefetch: true,
    }
}

fn workdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("txgain-it-pipe-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn full_pipeline_roundtrip() {
    let dir = workdir("full");
    let seq = 64;
    let stats = preprocess_corpus(&cfg(250), seq, 42, &dir).unwrap();
    assert_eq!(stats.shards.len(), 3);
    assert!(stats.reduction() > 0.5);

    // stage to "local SSD"
    let local = staging::stage_local(&stats.shards, &dir.join("local"))
        .unwrap();
    let (samples, got_seq) = load_dataset(&local).unwrap();
    assert_eq!(got_seq, seq);
    assert_eq!(samples.len(), 250);
    // every sample is CLS-prefixed and within vocabulary
    for s in &samples {
        assert_eq!(s.ids[0], special::CLS);
        assert!(s.len >= 2);
        assert!(s.ids.iter().all(|&id| (id as usize) < 350));
    }

    // two-rank epoch: loaders deliver the whole plan, masked correctly
    let ds = Arc::new(samples);
    let plan = EpochPlan::build(ds.len(), 2, 0, 42).unwrap();
    let masker = Masker::new(0.15, 350);
    let mut total_masked = 0usize;
    let mut total_real = 0usize;
    for rank in 0..2 {
        let mut pool = LoaderPool::spawn(
            ds.clone(), seq, &plan.per_rank[rank], 5, masker.clone(), 42,
            0, 2, 2, 0,
        )
        .unwrap();
        let mut steps = 0;
        while let Some(b) = pool.next_batch() {
            steps += 1;
            for (i, &l) in b.labels.iter().enumerate() {
                if l >= 0 {
                    total_masked += 1;
                    // a masked position must be a real token position
                    assert_eq!(b.attn_mask[i], 1.0);
                }
                if b.attn_mask[i] > 0.0 {
                    total_real += 1;
                }
            }
        }
        assert_eq!(steps, plan.per_rank[rank].len() / 5);
    }
    let rate = total_masked as f64 / total_real as f64;
    assert!((0.08..0.22).contains(&rate), "mask rate {rate}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn preprocessing_is_deterministic_across_runs() {
    let d1 = workdir("det1");
    let d2 = workdir("det2");
    let s1 = preprocess_corpus(&cfg(120), 32, 7, &d1).unwrap();
    let s2 = preprocess_corpus(&cfg(120), 32, 7, &d2).unwrap();
    assert_eq!(s1.raw_bytes, s2.raw_bytes);
    assert_eq!(s1.tokenized_bytes, s2.tokenized_bytes);
    let b1 = std::fs::read(&s1.shards[0]).unwrap();
    let b2 = std::fs::read(&s2.shards[0]).unwrap();
    assert_eq!(b1, b2, "shard bytes must be bit-identical");
    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

#[test]
fn different_seed_changes_the_corpus() {
    let d1 = workdir("seed1");
    let d2 = workdir("seed2");
    let s1 = preprocess_corpus(&cfg(60), 32, 1, &d1).unwrap();
    let s2 = preprocess_corpus(&cfg(60), 32, 2, &d2).unwrap();
    let b1 = std::fs::read(&s1.shards[0]).unwrap();
    let b2 = std::fs::read(&s2.shards[0]).unwrap();
    assert_ne!(b1, b2);
    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

#[test]
fn epoch_masks_differ_but_are_reproducible() {
    let dir = workdir("masks");
    let stats = preprocess_corpus(&cfg(100), 32, 5, &dir).unwrap();
    let (samples, seq) = load_dataset(&stats.shards).unwrap();
    let ds = Arc::new(samples);
    let masker = Masker::new(0.15, 350);
    let order: Vec<u32> = (0..100).collect();

    let collect = |epoch: u64| -> Vec<i32> {
        let mut pool = LoaderPool::spawn(ds.clone(), seq, &order, 10,
                                         masker.clone(), 5, epoch, 3, 2, 0)
            .unwrap();
        let mut all = Vec::new();
        while let Some(b) = pool.next_batch() {
            all.extend(b.input_ids);
        }
        all
    };
    let e0a = collect(0);
    let e0b = collect(0);
    let e1 = collect(1);
    assert_eq!(e0a, e0b, "same epoch must reproduce exactly");
    assert_ne!(e0a, e1, "different epochs must mask differently");
    std::fs::remove_dir_all(&dir).unwrap();
}
