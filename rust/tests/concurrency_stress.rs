//! Dead-peer teardown race stress: repeatedly kill a rank mid-stream
//! on every transport backend and assert the survivor observes an
//! *error*, never a hang — the runtime counterpart of the
//! model-checked alive-flag protocol in `tests/interleave_model.rs`
//! (which proves the store/load pairing; this test drives the real
//! backends through the same lifecycle under true parallelism).
//!
//! Each iteration varies how much traffic the dying rank pushes before
//! dropping its transport, sweeping the kill point across the
//! survivor's try_send/try_recv paths: mid-drain, mid-window,
//! before-first-message. A watchdog deadline turns any hang into a
//! named failure instead of a stuck CI job.

use std::time::{Duration, Instant};

use txgain::collectives::{Backend, Transport};

const TAG: u32 = 5_000;
const DEADLINE: Duration = Duration::from_secs(10);
const ITERATIONS: usize = 12;

fn kill_one_rank_mid_stream(backend: Backend) {
    for iter in 0..ITERATIONS {
        let mut comms = backend
            .world(2)
            .unwrap_or_else(|e| panic!("{backend}: world: {e}"));
        let mut dying = comms.pop().expect("rank 1");
        let mut survivor = comms.pop().expect("rank 0");

        // Rank 1: push a varying burst, touch the recv path, then die
        // abruptly (drop without any goodbye traffic).
        let burst = iter % 4;
        let killer = std::thread::spawn(move || {
            for i in 0..burst {
                let _ = dying.try_send(0, TAG, &[i as f32, -1.0]);
            }
            let _ = dying.try_recv(0, TAG);
            drop(dying);
        });

        // Rank 0: churn both nonblocking faces until the death shows
        // up as an error on either of them.
        let deadline = Instant::now() + DEADLINE;
        let mut observed_error = false;
        let mut drained = 0usize;
        while Instant::now() < deadline {
            match survivor.try_recv(1, TAG) {
                Err(_) => {
                    observed_error = true;
                    break;
                }
                Ok(Some(_)) => drained += 1,
                Ok(None) => {}
            }
            if survivor.try_send(1, TAG, &[0.5; 8]).is_err() {
                observed_error = true;
                break;
            }
            std::thread::yield_now();
        }
        killer.join().expect("dying-rank thread panicked");
        assert!(
            observed_error,
            "{backend} iter {iter}: rank 0 drained {drained} \
             messages but never saw rank 1's death as an error \
             within {DEADLINE:?} — dead peer must error, not hang"
        );
    }
}

#[test]
fn channel_dead_peer_errors_not_hangs() {
    kill_one_rank_mid_stream(Backend::Channel);
}

#[test]
fn shm_dead_peer_errors_not_hangs() {
    kill_one_rank_mid_stream(Backend::Shm);
}

#[test]
fn tcp_dead_peer_errors_not_hangs() {
    kill_one_rank_mid_stream(Backend::Tcp);
}
