//! Integration: the AOT bridge. Loads the real HLO artifacts produced
//! by `make artifacts`, compiles them on the PJRT CPU client and checks
//! the numerics end to end (python lowered it, rust must reproduce
//! training-math behaviour: sane initial loss, finite gradients, loss
//! decreasing under plain SGD).

use txgain::runtime::{Engine, HostParams, Manifest};
use txgain::util::Rng;

fn require_artifacts() -> Manifest {
    let dir = Manifest::default_dir();
    Manifest::load(&dir).expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    )
}

/// Deterministic synthetic batch with ~15 % masked positions.
fn batch(meta: &txgain::runtime::VariantMeta, seed: u64)
    -> (Vec<i32>, Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let n = meta.batch * meta.seq;
    let mut ids = Vec::with_capacity(n);
    let mut mask = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let pos = i % meta.seq;
        let real = pos < meta.seq - 4; // padded tail
        let id = 4 + rng.gen_range((meta.vocab - 4) as u64) as i32;
        mask.push(if real { 1.0 } else { 0.0 });
        if real && rng.next_f64() < 0.15 {
            ids.push(3); // [MASK]
            labels.push(id);
        } else {
            ids.push(if real { id } else { 0 });
            labels.push(-100);
        }
    }
    (ids, mask, labels)
}

#[test]
fn tiny_initial_loss_is_near_uniform() {
    let m = require_artifacts();
    let meta = m.variant("tiny").unwrap().clone();
    let engine = Engine::load(&m.dir, "tiny").unwrap();
    let params = HostParams::init(&meta, 42);
    let (ids, mask, labels) = batch(&meta, 7);
    let out = engine.execute_step(&params, &ids, &mask, &labels).unwrap();
    let uniform = (meta.vocab as f32).ln();
    assert!(
        (out.loss - uniform).abs() < 1.0,
        "initial loss {} should be near ln(vocab)={}",
        out.loss,
        uniform
    );
    assert_eq!(out.grads.len(), meta.grad_len);
    assert!(out.grads.iter().all(|g| g.is_finite()));
    let nonzero = out.grads.iter().filter(|g| **g != 0.0).count();
    assert!(nonzero > meta.grad_len / 2, "grads mostly zero: {nonzero}");
}

#[test]
fn execution_is_deterministic() {
    let m = require_artifacts();
    let meta = m.variant("tiny").unwrap().clone();
    let engine = Engine::load(&m.dir, "tiny").unwrap();
    let params = HostParams::init(&meta, 1);
    let (ids, mask, labels) = batch(&meta, 2);
    let a = engine.execute_step(&params, &ids, &mask, &labels).unwrap();
    let b = engine.execute_step(&params, &ids, &mask, &labels).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.grads, b.grads);
}

#[test]
fn loss_decreases_under_sgd_through_runtime() {
    // The core correctness signal for the whole AOT path: flat-gradient
    // slicing must line up with the parameter layout, or this diverges
    let m = require_artifacts();
    let meta = m.variant("tiny").unwrap().clone();
    let engine = Engine::load(&m.dir, "tiny").unwrap();
    let mut params = HostParams::init(&meta, 3);
    let (ids, mask, labels) = batch(&meta, 11);
    let mut losses = Vec::new();
    for _ in 0..8 {
        let out =
            engine.execute_step(&params, &ids, &mask, &labels).unwrap();
        losses.push(out.loss);
        params.zip_grads(&meta, &out.grads, |p, g| {
            for (pi, gi) in p.iter_mut().zip(g) {
                *pi -= 0.5 * gi;
            }
        });
    }
    assert!(
        losses.last().unwrap() + 0.1 < losses[0],
        "no learning through the runtime: {losses:?}"
    );
}

#[test]
fn all_cpu_variants_compile_and_execute() {
    let m = require_artifacts();
    for variant in ["tiny", "small"] {
        let meta = m.variant(variant).unwrap().clone();
        let engine = Engine::load(&m.dir, variant).unwrap();
        let params = HostParams::init(&meta, 5);
        let (ids, mask, labels) = batch(&meta, 9);
        let out =
            engine.execute_step(&params, &ids, &mask, &labels).unwrap();
        assert!(out.loss.is_finite(), "{variant}: loss {}", out.loss);
    }
}

#[test]
fn rejects_wrong_batch_buffers() {
    let m = require_artifacts();
    let meta = m.variant("tiny").unwrap().clone();
    let engine = Engine::load(&m.dir, "tiny").unwrap();
    let params = HostParams::init(&meta, 5);
    let bad = vec![0i32; 3];
    assert!(engine
        .execute_step(&params, &bad, &[0.0; 3], &[0; 3])
        .is_err());
}

#[test]
fn fully_masked_labels_give_zero_loss_and_grads() {
    let m = require_artifacts();
    let meta = m.variant("tiny").unwrap().clone();
    let engine = Engine::load(&m.dir, "tiny").unwrap();
    let params = HostParams::init(&meta, 5);
    let n = meta.batch * meta.seq;
    let ids = vec![4i32; n];
    let mask = vec![1.0f32; n];
    let labels = vec![-100i32; n]; // nothing to predict
    let out = engine.execute_step(&params, &ids, &mask, &labels).unwrap();
    assert_eq!(out.loss, 0.0);
    assert!(out.grads.iter().all(|&g| g == 0.0));
}
