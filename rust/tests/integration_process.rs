//! Integration: the process-per-rank world (`txgain worker` /
//! `txgain launch`) — real subprocesses, real sockets, no threads
//! standing in for processes.
//!
//! The tentpole property: a 4-rank `txgain launch` multi-process tcp
//! world must produce a training trajectory BIT-IDENTICAL to the
//! in-process 4-rank tcp world from the same config (steps.csv's
//! deterministic columns and the checkpoint file bytes). Process
//! boundaries are a deployment knob; they must never be a numerics
//! knob.
//!
//! Every rendezvous failure mode is additionally asserted through the
//! real CLI under a watchdog deadline: absent rank, duplicate rank,
//! config-hash mismatch, world mismatch — all named errors, never
//! hangs (the `concurrency_stress` discipline, one process level up).

use std::io::Read;
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::{Command, ExitStatus, Stdio};
use std::time::{Duration, Instant};

use txgain::config::{presets, LaunchConfig};
use txgain::coordinator;
use txgain::coordinator::rendezvous::{serve, PROBE_HASH};
use txgain::runtime::Manifest;

const BIN: &str = env!("CARGO_BIN_EXE_txgain");

fn workdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("txgain-it-proc-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Run `cmd` to completion under a hard deadline: if the subprocess
/// is still alive past `secs`, kill it and fail the test by name —
/// a hung bootstrap is exactly the bug class this suite polices.
fn run_with_deadline(mut cmd: Command, secs: u64, what: &str)
    -> (ExitStatus, String, String) {
    cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
    let mut child = cmd.spawn().unwrap();
    let mut out_pipe = child.stdout.take().unwrap();
    let mut err_pipe = child.stderr.take().unwrap();
    let out_h = std::thread::spawn(move || {
        let mut s = String::new();
        let _ = out_pipe.read_to_string(&mut s);
        s
    });
    let err_h = std::thread::spawn(move || {
        let mut s = String::new();
        let _ = err_pipe.read_to_string(&mut s);
        s
    });
    let deadline = Instant::now() + Duration::from_secs(secs);
    let status = loop {
        if let Some(st) = child.try_wait().unwrap() {
            break st;
        }
        if Instant::now() >= deadline {
            let _ = child.kill();
            let _ = child.wait();
            panic!("{what}: subprocess still running after {secs}s \
                    (error-not-hang violated)");
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    (status, out_h.join().unwrap(), err_h.join().unwrap())
}

fn worker_cmd(rank: usize, world: usize, rendezvous: &str,
              dir: &Path, extra: &[&str]) -> Command {
    let mut c = Command::new(BIN);
    c.arg("worker")
        .arg(format!("--rank={rank}"))
        .arg(format!("--world={world}"))
        .arg(format!("--rendezvous={rendezvous}"))
        .arg(format!("--workdir={}", dir.display()));
    for e in extra {
        c.arg(e);
    }
    c
}

/// A short-fused leader for the failure-mode tests: everything it
/// polices should resolve in well under a second on loopback.
fn fast_rz() -> LaunchConfig {
    LaunchConfig {
        rendezvous_timeout_secs: 3.0,
        handshake_timeout_secs: 2.0,
        connect_backoff_ms: 5,
    }
}

fn leader_on_loopback() -> (TcpListener, String) {
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    (l, addr)
}

// ---------------------------------------------------------------- CLI

#[test]
fn cli_version_and_flag_syntax() {
    let mut c = Command::new(BIN);
    c.arg("--version");
    let (st, out, _) = run_with_deadline(c, 30, "txgain --version");
    assert!(st.success());
    assert!(out.contains(env!("CARGO_PKG_VERSION")),
            "--version output: {out}");

    // --key=value spelling is accepted
    let mut c = Command::new(BIN);
    c.arg("sim").arg("--nodes=2");
    let (st, out, err) = run_with_deadline(c, 60, "txgain sim");
    assert!(st.success(), "sim --nodes=2 failed:\n{out}\n{err}");

    // a repeated flag is a typo'd command line, not an override
    let mut c = Command::new(BIN);
    c.arg("sim").arg("--nodes").arg("2").arg("--nodes=3");
    let (st, _, err) = run_with_deadline(c, 30, "txgain dup flag");
    assert!(!st.success());
    assert!(err.contains("duplicate flag --nodes"), "stderr: {err}");
}

// -------------------------------------------------------- probe world

#[test]
fn launch_probe_assembles_a_four_process_world() {
    let dir = workdir("probe4");
    let mut c = Command::new(BIN);
    c.arg("launch")
        .arg("--workers=4")
        .arg("--probe")
        .arg(format!("--workdir={}", dir.display()));
    let (st, out, err) =
        run_with_deadline(c, 120, "launch --workers 4 --probe");
    assert!(st.success(), "probe launch failed:\n{out}\n{err}");
    for rank in 0..4 {
        assert!(out.contains(&format!("probe rank {rank}: ok")),
                "rank {rank} never reported; stdout:\n{out}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------ failure modes

#[test]
fn missing_rank_fails_the_world_by_name() {
    let dir = workdir("missing");
    let (l, addr) = leader_on_loopback();
    let rz = fast_rz();
    let leader =
        std::thread::spawn(move || serve(l, 2, PROBE_HASH, &rz));
    // rank 0 joins; rank 1 never exists
    let (st, _, err) = run_with_deadline(
        worker_cmd(0, 2, &addr, &dir, &["--probe"]), 30,
        "worker in a half world");
    assert!(!st.success(), "worker should fail when a rank is absent");
    assert!(err.contains("never arrived"), "stderr: {err}");
    let lerr = leader.join().unwrap().unwrap_err().to_string();
    assert!(lerr.contains("rank(s) 1"), "leader error: {lerr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn duplicate_rank_id_is_rejected() {
    let dir = workdir("dup");
    let (l, addr) = leader_on_loopback();
    let rz = fast_rz();
    let leader =
        std::thread::spawn(move || serve(l, 2, PROBE_HASH, &rz));
    let h: Vec<_> = (0..2)
        .map(|i| {
            let cmd = worker_cmd(0, 2, &addr, &dir, &["--probe"]);
            std::thread::spawn(move || {
                run_with_deadline(cmd, 30,
                                  &format!("duplicate worker {i}"))
            })
        })
        .collect();
    let results: Vec<_> =
        h.into_iter().map(|t| t.join().unwrap()).collect();
    let lerr = leader.join().unwrap().unwrap_err().to_string();
    assert!(lerr.contains("duplicate rank 0"), "leader: {lerr}");
    for (st, _, _) in &results {
        assert!(!st.success(),
                "a worker exited cleanly from a duplicate-rank world");
    }
    assert!(results.iter().any(|(_, _, e)| e.contains("duplicate rank")),
            "no worker saw the duplicate-rank error: {:?}",
            results.iter().map(|(_, _, e)| e.clone())
                .collect::<Vec<_>>());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn config_hash_mismatch_is_rejected() {
    let dir = workdir("hash");
    let (l, addr) = leader_on_loopback();
    let rz = fast_rz();
    // the leader expects a training config's hash; the probe worker
    // announces the PROBE_HASH sentinel — mixed worlds must not wire
    let leader =
        std::thread::spawn(move || serve(l, 1, 0x1234_5678, &rz));
    let (st, _, err) = run_with_deadline(
        worker_cmd(0, 1, &addr, &dir, &["--probe"]), 30,
        "config-mismatch worker");
    assert!(!st.success());
    assert!(err.contains("config mismatch"), "stderr: {err}");
    assert!(leader.join().unwrap().is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn world_size_mismatch_is_rejected() {
    let dir = workdir("world");
    let (l, addr) = leader_on_loopback();
    let rz = fast_rz();
    let leader =
        std::thread::spawn(move || serve(l, 2, PROBE_HASH, &rz));
    let (st, _, err) = run_with_deadline(
        worker_cmd(0, 3, &addr, &dir, &["--probe"]), 30,
        "world-mismatch worker");
    assert!(!st.success());
    assert!(err.contains("world"), "stderr: {err}");
    assert!(leader.join().unwrap().is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

// -------------------------------------------------------- bit-identity

fn load_csv(path: &Path) -> Vec<Vec<String>> {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    text.lines()
        .skip(1) // header
        .map(|l| l.split(',').map(str::to_string).collect())
        .collect()
}

/// The acceptance gate: same config, two world shapes — 4 rank
/// threads over tcp loopback vs 4 worker processes over the
/// rendezvous-wired tcp mesh — bit-identical trajectories. Columns
/// compared are the deterministic ones (step, loss, lr, comm buffer/
/// wire bytes); timing and per-step loader attribution legitimately
/// vary run to run. The step-6 checkpoint must match byte for byte.
#[test]
fn launch_world_matches_in_process_training_bitwise() {
    let artifacts = Manifest::default_dir();
    if Manifest::load(&artifacts).is_err() {
        eprintln!("skipping: no compiled artifacts (`make artifacts`)");
        return;
    }
    let mut cfg = presets::quickstart();
    cfg.cluster.nodes = 4;
    cfg.cluster.gpus_per_node = 1;
    cfg.training.steps = 6;
    cfg.training.log_every = 0;
    cfg.training.checkpoint_every = 6;
    cfg.training.transport = "tcp".to_string();
    cfg.data.corpus_samples = 256;
    cfg.validate().unwrap();

    let base = workdir("bitident");
    let inproc = base.join("inproc");
    let out = coordinator::run(&cfg, &artifacts, &inproc).unwrap();
    assert_eq!(out.report.records.len(), 6);

    let cfg_path = base.join("cfg.json");
    std::fs::write(&cfg_path, cfg.to_json_string()).unwrap();
    let multi = base.join("multi");
    let mut c = Command::new(BIN);
    c.arg("launch")
        .arg("--workers=4")
        .arg(format!("--config={}", cfg_path.display()))
        .arg(format!("--workdir={}", multi.display()))
        .arg(format!("--artifacts={}", artifacts.display()));
    let (st, lout, lerr) =
        run_with_deadline(c, 300, "launch training world");
    assert!(st.success(), "launch training failed:\n{lout}\n{lerr}");

    // steps.csv columns: 0 step, 1 loss, 2 lr, 8 comm_buffer_bytes,
    // 9 comm_wire_bytes (schema locked by train::metrics tests)
    let a = load_csv(&inproc.join("steps.csv"));
    let b = load_csv(&multi.join("steps.csv"));
    assert_eq!(a.len(), b.len(), "step counts differ");
    for (ra, rb) in a.iter().zip(&b) {
        for &col in &[0usize, 1, 2, 8, 9] {
            assert_eq!(ra[col], rb[col],
                       "trajectories diverge at column {col}:\n  \
                        in-process {ra:?}\n  multi-proc {rb:?}");
        }
    }
    let ck_a = std::fs::read(
        inproc.join("checkpoints/step-000006.ckpt")).unwrap();
    let ck_b = std::fs::read(
        multi.join("rank-0/checkpoints/step-000006.ckpt")).unwrap();
    assert_eq!(ck_a, ck_b,
               "checkpoint bytes differ between world shapes");
    let _ = std::fs::remove_dir_all(&base);
}
