//! Integration: the full training stack — coordinator pipeline, the
//! multi-rank DP trainer over PJRT, real collectives, optimizer,
//! checkpoints. Uses the tiny variant to keep compile time small.

use txgain::config::{presets, Config};
use txgain::coordinator;
use txgain::runtime::Manifest;

fn artifacts() -> std::path::PathBuf {
    let dir = Manifest::default_dir();
    Manifest::load(&dir).expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );
    dir
}

fn workdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("txgain-it-train-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tiny_cfg(steps: usize) -> Config {
    let mut cfg = presets::quickstart();
    cfg.training.steps = steps;
    cfg.data.corpus_samples = 512;
    cfg
}

#[test]
fn loss_decreases_over_training() {
    let dir = workdir("loss");
    let mut cfg = tiny_cfg(50);
    cfg.training.lr = 1e-3; // tiny model: push hard so 50 steps show it
    cfg.training.warmup_steps = 5; // don't spend the test warming up
    let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    let r = &out.report;
    assert_eq!(r.records.len(), 50);
    let first = r.first_loss().unwrap();
    let tail = r.tail_loss(5).unwrap();
    assert!(
        tail < first - 0.5,
        "loss did not fall: {first} -> {tail}"
    );
    // report files exist and parse
    let json = std::fs::read_to_string(dir.join("report.json")).unwrap();
    let v = txgain::util::json::Value::parse(&json).unwrap();
    assert_eq!(v.req("steps").unwrap().as_usize().unwrap(), 50);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn ring_and_tree_allreduce_agree_exactly() {
    // the trajectory is a pure function of the config modulo the
    // collective algorithm — both must produce identical losses
    let run_with = |algo: &str| -> Vec<f32> {
        let dir = workdir(&format!("algo-{algo}"));
        let mut cfg = tiny_cfg(6);
        cfg.training.allreduce = algo.into();
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let losses =
            out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        losses
    };
    let ring = run_with("ring");
    let tree = run_with("tree");
    // identical schedules & data; fp reduction order differs between
    // algorithms, so allow tiny drift but require near-exact agreement
    assert_eq!(ring.len(), tree.len());
    for (a, b) in ring.iter().zip(&tree) {
        assert!((a - b).abs() < 5e-4, "ring {a} vs tree {b}");
    }
}

#[test]
fn bucketed_overlap_matches_monolithic_allreduce() {
    // the quickstart preset's 0.05 MB bucket splits the tiny model's
    // gradient into several buckets; the trajectory must match the
    // monolithic (overlap off) run — fp accumulation order inside the
    // collective differs with the buffer split, so allow the same tiny
    // drift the ring-vs-tree test does (bit-exactness of the bucketed
    // collective itself is asserted in collectives::bucket's tests)
    let run_with = |overlap: bool| -> Vec<f32> {
        let dir = workdir(&format!("overlap-{overlap}"));
        let mut cfg = tiny_cfg(6);
        cfg.training.overlap_comm = overlap;
        // isolate the overlap knob: quickstart defaults to zero_stage 1,
        // which (validly) refuses to run without overlap
        cfg.training.zero_stage = 0;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let losses =
            out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        losses
    };
    let bucketed = run_with(true);
    let mono = run_with(false);
    assert_eq!(bucketed.len(), mono.len());
    for (a, b) in bucketed.iter().zip(&mono) {
        assert!((a - b).abs() < 5e-4, "bucketed {a} vs monolithic {b}");
    }
}

#[test]
fn zero1_matches_replicated_trajectory_exactly() {
    // quickstart runs zero_stage 1 (reduce-scatter → shard step →
    // all-gather). Because ring all-reduce IS reduce-scatter +
    // all-gather, the reduced value every rank sees per element is
    // computed once on its owner either way — so the sharded run must
    // reproduce the replicated trajectory BIT-identically, not just
    // approximately (the artifact-free property test covers worlds
    // {1,2,4,8}; this covers the full PJRT stack).
    let run_with = |stage: usize| -> Vec<f32> {
        let dir = workdir(&format!("zero-{stage}"));
        let mut cfg = tiny_cfg(6);
        cfg.training.zero_stage = stage;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let losses =
            out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        losses
    };
    let replicated = run_with(0);
    assert_eq!(run_with(1), replicated);
    // stage 2 reduces the same buckets to the same owners; freeing
    // the non-owned spans after each reduce-scatter touches memory,
    // never values — still bit-identical with the f32 grad store
    assert_eq!(run_with(2), replicated);
}

#[test]
fn every_transport_backend_trains_bit_identically() {
    // the transport moves bytes, the math never changes: the full
    // pipeline (ZeRO-1 quickstart: bucketed RS → shard step → AG →
    // sharded checkpointless run) must produce the exact same loss
    // trajectory on channel mailboxes, shm slot rings and tcp sockets
    let run_with = |transport: &str| -> Vec<f32> {
        let dir = workdir(&format!("tp-{transport}"));
        let mut cfg = tiny_cfg(4);
        cfg.training.transport = transport.into();
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let losses =
            out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        losses
    };
    let channel = run_with("channel");
    assert_eq!(channel.len(), 4);
    for t in ["shm", "tcp"] {
        assert_eq!(run_with(t), channel,
                   "transport {t} changed the trajectory");
    }
}

#[test]
fn comm_engine_matches_blocking_trajectory_exactly() {
    // the tentpole numerics guarantee: driving the bucketed
    // collectives through the async comm engine changes WHEN bytes
    // move, never WHAT they compute — loss bits and wire bytes must
    // equal the blocking path's on every backend, replicated and
    // ZeRO-1 (quickstart runs stage 1 with an uneven first bucket)
    let run_with = |engine: bool, transport: &str, zero: usize|
        -> Vec<(u32, u64, u64)> {
        let dir = workdir(&format!("eng-{engine}-{transport}-{zero}"));
        let mut cfg = tiny_cfg(5);
        cfg.training.comm_engine = engine;
        cfg.training.transport = transport.into();
        cfg.training.zero_stage = zero;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let fp = out.report.records.iter()
            .map(|r| (r.loss.to_bits(), r.comm_buffer_bytes,
                      r.comm_wire_bytes))
            .collect();
        std::fs::remove_dir_all(&dir).unwrap();
        fp
    };
    for zero in [0usize, 1] {
        for t in ["channel", "shm", "tcp"] {
            assert_eq!(run_with(true, t, zero),
                       run_with(false, t, zero),
                       "engine changed the trajectory or traffic \
                        (transport {t}, zero {zero})");
        }
    }
}

#[test]
fn comm_exposed_ms_is_recorded_and_bounded() {
    // the measured twin of the sim's comm-exposed column: present in
    // steps.csv/report.json, and never larger than the comm time the
    // trainer thread saw
    let dir = workdir("exposed");
    let cfg = tiny_cfg(4);
    let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    for r in &out.report.records {
        assert!(r.comm_exposed_secs.is_finite()
                    && r.comm_exposed_secs >= 0.0);
        assert!(r.comm_exposed_secs <= r.comm_secs + 1e-9,
                "exposed {} > comm {}", r.comm_exposed_secs,
                r.comm_secs);
    }
    let csv = std::fs::read_to_string(dir.join("steps.csv")).unwrap();
    assert!(csv.lines().next().unwrap().contains("comm_exposed_ms"),
            "missing comm_exposed_ms column");
    let json = std::fs::read_to_string(dir.join("report.json")).unwrap();
    let v = txgain::util::json::Value::parse(&json).unwrap();
    assert!(v.req("comm_exposed_ms").unwrap().as_f64().unwrap() >= 0.0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn remainder_rolls_into_the_next_epoch() {
    // data-plane item (c): with a corpus that leaves a per-epoch
    // remainder (33/rank at batch 4 → carry walks 0,1,2,3,…), the
    // carried samples extend later epochs instead of vanishing — the
    // run sees more distinct steps per wall-epoch and still trains
    // deterministically
    let dir = workdir("carryrun");
    let mut cfg = tiny_cfg(20);
    cfg.data.corpus_samples = 66; // 33/rank, batch 4: 8 steps + carry
    let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    assert_eq!(out.report.records.len(), 20);
    std::fs::remove_dir_all(&dir).unwrap();

    // and the run is reproducible bit for bit
    let dir2 = workdir("carryrun2");
    let out2 = coordinator::run(&cfg, &artifacts(), &dir2).unwrap();
    let a: Vec<u32> = out.report.records.iter()
        .map(|r| r.loss.to_bits()).collect();
    let b: Vec<u32> = out2.report.records.iter()
        .map(|r| r.loss.to_bits()).collect();
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir2).unwrap();
}

#[test]
fn world_size_one_also_trains() {
    let dir = workdir("solo");
    let mut cfg = tiny_cfg(5);
    cfg.cluster.nodes = 1;
    let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    assert_eq!(out.report.world, 1);
    assert_eq!(out.report.records.len(), 5);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn checkpoints_are_written_and_loadable() {
    let dir = workdir("ckpt");
    let mut cfg = tiny_cfg(6);
    cfg.training.checkpoint_every = 3;
    coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    let ck3 = dir.join("checkpoints/step-000003.ckpt");
    let ck6 = dir.join("checkpoints/step-000006.ckpt");
    assert!(ck3.exists() && ck6.exists());
    let ck = txgain::train::checkpoint::load(&ck6).unwrap();
    assert_eq!(ck.step(), 6);
    assert_eq!(ck.params.total_len() as u64,
               presets::model_tiny().param_count());
    assert!(ck.m.iter().any(|&x| x != 0.0), "optimizer state empty");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tiny_cache_matches_default_cache_bit_for_bit() {
    // the memory-bound acceptance criterion: a block cache smaller
    // than ONE shard (the loaders thrash disk constantly) must still
    // produce the exact trajectory of an ample cache — residency is a
    // performance knob, never a numerics knob
    let run_with = |cache_mb: f64| {
        let dir = workdir(&format!("cache-{cache_mb}"));
        let mut cfg = tiny_cfg(6);
        cfg.data.cache_mb = cache_mb;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let r = &out.report;
        let losses: Vec<u32> =
            r.records.iter().map(|x| x.loss.to_bits()).collect();
        let bytes = r.loader_bytes_read();
        std::fs::remove_dir_all(&dir).unwrap();
        (losses, bytes)
    };
    // quickstart: 512 corpus / 256-sample shards ≈ 33 KB per shard;
    // 0.01 MiB ≈ 10 KB keeps less than one shard resident
    let (tiny, tiny_bytes) = run_with(0.01);
    let (ample, ample_bytes) = run_with(16.0);
    assert_eq!(tiny, ample, "cache size changed the trajectory");
    // and the tiny cache really did hit the disk harder
    assert!(tiny_bytes > ample_bytes,
            "thrash {tiny_bytes} !> warm {ample_bytes}");
    assert!(ample_bytes > 0, "streaming path must measure its reads");
}

#[test]
fn mid_epoch_resume_is_bit_identical() {
    // the resume acceptance criterion: checkpoint mid-epoch, resume in
    // a fresh workdir, and the continuation must reproduce the
    // uninterrupted run's remaining StepRecords bit-identically — loss
    // bits AND comm traffic. 20 steps over 8-step epochs with a save
    // at 12 puts the cut in the middle of epoch 1 and the continuation
    // across two more epoch boundaries.
    let mut cfg = tiny_cfg(20);
    cfg.data.corpus_samples = 64; // 32/rank -> 8 steps per epoch
    cfg.training.checkpoint_every = 6;

    let dir_a = workdir("resume-full");
    let full = coordinator::run(&cfg, &artifacts(), &dir_a).unwrap();
    assert_eq!(full.report.records.len(), 20);
    let ckpt = dir_a.join("checkpoints/step-000012.ckpt");
    assert!(ckpt.exists());
    let ck = txgain::train::checkpoint::load(&ckpt).unwrap();
    assert_eq!(ck.progress.epoch, 1, "cut must land mid-epoch");
    assert_eq!(ck.progress.epoch_step, 4);

    let dir_b = workdir("resume-cont");
    let cont = coordinator::run_resumable(&cfg, &artifacts(), &dir_b,
                                          Some(&ckpt))
        .unwrap();
    let tail = &full.report.records[12..];
    let resumed = &cont.report.records;
    assert_eq!(resumed.len(), tail.len());
    for (a, b) in tail.iter().zip(resumed) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(),
                   "step {}: loss {} vs resumed {}", a.step, a.loss,
                   b.loss);
        assert_eq!(a.comm_buffer_bytes, b.comm_buffer_bytes);
        assert_eq!(a.comm_wire_bytes, b.comm_wire_bytes);
    }
    std::fs::remove_dir_all(&dir_a).unwrap();
    std::fs::remove_dir_all(&dir_b).unwrap();
}

#[test]
fn zero1_sharded_checkpoint_resumes_bit_identically() {
    // same property through the ZeRO-1 path: the merged world-size-
    // independent checkpoint restores per-rank moment shards and the
    // data cursor
    let mut cfg = tiny_cfg(10);
    cfg.training.zero_stage = 1;
    cfg.data.corpus_samples = 64;
    cfg.training.checkpoint_every = 4;

    let dir_a = workdir("zresume-full");
    let full = coordinator::run(&cfg, &artifacts(), &dir_a).unwrap();
    let ckpt = dir_a.join("checkpoints/step-000004.ckpt");
    let dir_b = workdir("zresume-cont");
    let cont = coordinator::run_resumable(&cfg, &artifacts(), &dir_b,
                                          Some(&ckpt))
        .unwrap();
    let tail: Vec<u32> = full.report.records[4..]
        .iter().map(|r| r.loss.to_bits()).collect();
    let resumed: Vec<u32> = cont.report.records
        .iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(tail, resumed);
    std::fs::remove_dir_all(&dir_a).unwrap();
    std::fs::remove_dir_all(&dir_b).unwrap();
}

#[test]
fn pre_carry_v2_cursor_is_refused_only_when_the_stream_shifted() {
    // a checkpoint whose version field says v2 (pre-carry build) has a
    // cursor measured against carry-free epoch streams. Under carry
    // geometry the same (epoch, epoch_step) now names different
    // samples → refuse; under carry-free geometry nothing moved →
    // resume normally.
    let patch_version = |ckpt: &std::path::Path, v: u32| {
        let mut bytes = std::fs::read(ckpt).unwrap();
        bytes[4..8].copy_from_slice(&v.to_le_bytes());
        std::fs::write(ckpt, &bytes).unwrap();
    };

    // carry geometry: 33/rank at batch 4 carries 1,2,3,… per epoch
    let mut cfg = tiny_cfg(12);
    cfg.data.corpus_samples = 66;
    cfg.training.checkpoint_every = 10; // epoch 1 (epoch 0 has 8 steps)
    let dir_a = workdir("v2carry-save");
    coordinator::run(&cfg, &artifacts(), &dir_a).unwrap();
    let ckpt = dir_a.join("checkpoints/step-000010.ckpt");
    patch_version(&ckpt, 2);
    let dir_b = workdir("v2carry-resume");
    let err = coordinator::run_resumable(&cfg, &artifacts(), &dir_b,
                                         Some(&ckpt))
        .unwrap_err()
        .to_string();
    assert!(err.contains("carr"), "unhelpful error: {err}");
    std::fs::remove_dir_all(&dir_a).unwrap();
    let _ = std::fs::remove_dir_all(&dir_b);

    // carry-free geometry: 32/rank at batch 4 — v2 cursors stay valid
    let mut cfg = tiny_cfg(12);
    cfg.data.corpus_samples = 64;
    cfg.training.checkpoint_every = 10;
    let dir_a = workdir("v2free-save");
    let full = coordinator::run(&cfg, &artifacts(), &dir_a).unwrap();
    let ckpt = dir_a.join("checkpoints/step-000010.ckpt");
    patch_version(&ckpt, 2);
    let dir_b = workdir("v2free-resume");
    let cont = coordinator::run_resumable(&cfg, &artifacts(), &dir_b,
                                          Some(&ckpt))
        .unwrap();
    let tail: Vec<u32> = full.report.records[10..]
        .iter().map(|r| r.loss.to_bits()).collect();
    let resumed: Vec<u32> = cont.report.records
        .iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(tail, resumed, "v2 cursor broke a carry-free resume");
    std::fs::remove_dir_all(&dir_a).unwrap();
    std::fs::remove_dir_all(&dir_b).unwrap();
}

#[test]
fn resume_across_changed_epoch_geometry_is_refused() {
    // params/moments are world-size independent, but the mid-epoch
    // data cursor is only meaningful in the geometry that saved it —
    // resuming with a different corpus (→ different steps/epoch) must
    // be a clean error, not a silently reshuffled data order
    let mut cfg = tiny_cfg(10);
    cfg.data.corpus_samples = 64;
    cfg.training.checkpoint_every = 5;
    let dir_a = workdir("geom-save");
    coordinator::run(&cfg, &artifacts(), &dir_a).unwrap();
    let ckpt = dir_a.join("checkpoints/step-000005.ckpt");

    let mut cfg2 = cfg.clone();
    cfg2.data.corpus_samples = 128; // 16 steps/epoch instead of 8
    let dir_b = workdir("geom-resume");
    let err = coordinator::run_resumable(&cfg2, &artifacts(), &dir_b,
                                         Some(&ckpt))
        .unwrap_err()
        .to_string();
    assert!(err.contains("geometry"), "unhelpful error: {err}");
    std::fs::remove_dir_all(&dir_a).unwrap();
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn oversized_batch_errors_instead_of_spinning() {
    // regression for the infinite epoch loop: a batch no rank can fill
    // used to build empty epochs forever; it must be a clean error
    let dir = workdir("emptyepoch");
    let mut cfg = tiny_cfg(5);
    cfg.data.corpus_samples = 6; // 3 per rank < batch 4
    let err = coordinator::run(&cfg, &artifacts(), &dir)
        .unwrap_err()
        .to_string();
    assert!(err.contains("exceeds"), "unhelpful error: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn steps_csv_carries_loader_stream_columns() {
    let dir = workdir("loadercols");
    let cfg = tiny_cfg(4);
    let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    let csv = std::fs::read_to_string(dir.join("steps.csv")).unwrap();
    let head = csv.lines().next().unwrap();
    assert!(head.contains("loader_bytes") && head.contains("cache_hit_rate"),
            "missing loader columns: {head}");
    let json = std::fs::read_to_string(dir.join("report.json")).unwrap();
    let v = txgain::util::json::Value::parse(&json).unwrap();
    let bytes = v.req("loader_bytes_read").unwrap().as_f64().unwrap();
    assert!(bytes > 0.0, "no loader bytes measured");
    // cross-check against the staging model: the measured stream,
    // priced by the same storage model the estimate uses, is a finite
    // positive time bounded by the full-dataset-per-epoch estimate
    let per_node = (bytes as u64) * out.report.world as u64
        / cfg.cluster.nodes as u64;
    let priced = txgain::data::staging::price_read(
        &cfg.cluster, cfg.data.staging, per_node);
    assert!(priced.is_finite() && priced > 0.0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn network_direct_staging_also_works() {
    // functional equivalence of the two staging policies (perf differs,
    // numerics must not)
    let run_with = |policy| -> Vec<f32> {
        let dir = workdir(&format!("stag-{policy:?}"));
        let mut cfg = tiny_cfg(4);
        cfg.data.staging = policy;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let l = out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        l
    };
    use txgain::config::StagingPolicy as SP;
    assert_eq!(run_with(SP::LocalCopy), run_with(SP::NetworkDirect));
}

#[test]
fn loader_count_does_not_change_numerics() {
    let run_with = |loaders: usize| -> Vec<f32> {
        let dir = workdir(&format!("ld-{loaders}"));
        let mut cfg = tiny_cfg(4);
        cfg.data.loaders_per_gpu = loaders;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let l = out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        l
    };
    assert_eq!(run_with(1), run_with(4));
}

#[test]
fn bf16_wire_halves_measured_bytes_and_tracks_the_f32_loss() {
    // the tentpole acceptance, through the full trainer: switching
    // training.wire_codec to bf16 must halve every step's measured
    // comm_wire_bytes EXACTLY (payload counters exclude framing, which
    // rides wire_overhead_bytes), and int8 must quarter them, while
    // the host-side buffer traffic stays codec-invariant. The bf16
    // trajectory drifts from f32 only by wire rounding — a few 1e-3
    // over 6 tiny-model steps — and int8+EF stays in the same basin.
    let run_with = |codec: &str| -> Vec<(f32, u64, u64)> {
        let dir = workdir(&format!("codec-{codec}"));
        let mut cfg = tiny_cfg(6);
        cfg.training.wire_codec = codec.into();
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let rows = out.report.records.iter()
            .map(|r| (r.loss, r.comm_buffer_bytes, r.comm_wire_bytes))
            .collect();
        std::fs::remove_dir_all(&dir).unwrap();
        rows
    };
    let f32_run = run_with("f32");
    let bf16_run = run_with("bf16");
    let int8_run = run_with("int8");
    assert_eq!(f32_run.len(), 6);
    for (i, ((fl, fb, fw), (bl, bb, bw))) in
        f32_run.iter().zip(&bf16_run).enumerate()
    {
        assert_eq!(fb, bb, "step {i}: buffer bytes moved with codec");
        assert_eq!(*fw, bw * 2,
                   "step {i}: bf16 wire {bw} != half of f32 {fw}");
        assert!((fl - bl).abs() < 0.05,
                "step {i}: bf16 loss {bl} far from f32 {fl}");
    }
    for (i, ((fl, fb, fw), (il, ib, iw))) in
        f32_run.iter().zip(&int8_run).enumerate()
    {
        assert_eq!(fb, ib, "step {i}: buffer bytes moved with codec");
        assert_eq!(*fw, iw * 4,
                   "step {i}: int8 wire {iw} != quarter of f32 {fw}");
        assert!((fl - il).abs() < 0.2,
                "step {i}: int8 loss {il} far from f32 {fl}");
    }
}

#[test]
fn int8_error_feedback_still_converges() {
    // the EF convergence criterion: the 1-byte wire quantizes every
    // hop to 255 levels, but the carried residuals re-inject what
    // quantization dropped, so the real training loss must still fall
    // like the f32 run in loss_decreases_over_training does
    let dir = workdir("int8-loss");
    let mut cfg = tiny_cfg(50);
    cfg.training.wire_codec = "int8".into();
    cfg.training.lr = 1e-3;
    cfg.training.warmup_steps = 5;
    let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    let r = &out.report;
    assert_eq!(r.records.len(), 50);
    let first = r.first_loss().unwrap();
    let tail = r.tail_loss(5).unwrap();
    assert!(tail < first - 0.5,
            "int8+EF loss did not fall: {first} -> {tail}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn grad_peak_bytes_matches_the_closed_form_model() {
    // the trainer measures its gradient-plane residency with a real
    // byte counter; RankMemory::grad_peak_bytes replays the same
    // schedule analytically. Every (driver, stage) cell of the real
    // PJRT run must land exactly on the model — records come from
    // rank 0, so the closed form is evaluated for rank 0 too.
    use txgain::collectives::{BucketPlan, GradDtype, RankMemory};
    let run_with = |engine: bool, stage: usize, dtype: &str| -> u64 {
        let dir = workdir(&format!("gpeak-{engine}-{stage}-{dtype}"));
        let mut cfg = tiny_cfg(3);
        cfg.training.comm_engine = engine;
        cfg.training.zero_stage = stage;
        cfg.training.grad_dtype = dtype.into();
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let peak = out.report.grad_peak_bytes();
        std::fs::remove_dir_all(&dir).unwrap();
        peak
    };
    let cfg = tiny_cfg(3);
    // the artifact loader enforces grad_len == sum of param sizes,
    // which is the preset's param_count (see checkpoint round-trip)
    let grad_len = presets::model_tiny().param_count() as usize;
    let world = cfg.world_size();
    let plan = BucketPlan::new_with_first(grad_len,
                                          cfg.training.bucket_mb,
                                          cfg.training.first_bucket_mb);
    for engine in [false, true] {
        for stage in [0usize, 1, 2] {
            let want = RankMemory::grad_peak_bytes(
                Some(&plan), grad_len, 0, world, stage,
                GradDtype::F32, engine);
            let got = run_with(engine, stage, "f32");
            assert_eq!(got, want,
                       "engine={engine} stage={stage}: measured \
                        {got} != closed form {want}");
        }
        // the bf16 store halves the shard-resident term at stage 2
        let want16 = RankMemory::grad_peak_bytes(
            Some(&plan), grad_len, 0, world, 2, GradDtype::Bf16,
            engine);
        let want32 = RankMemory::grad_peak_bytes(
            Some(&plan), grad_len, 0, world, 2, GradDtype::F32,
            engine);
        assert!(want16 < want32,
                "model says bf16 does not shrink the store");
        let got16 = run_with(engine, 2, "bf16");
        assert_eq!(got16, want16,
                   "engine={engine} bf16: measured {got16} != closed \
                    form {want16}");
    }
}

#[test]
fn bf16_grad_store_trains_deterministically() {
    // the bf16 gradient store rounds (RNE) once per bucket on the
    // accumulate path; rounding is a pure function, so two identical
    // runs must agree to the bit, and the trajectory must stay close
    // to the f32 store on this tiny model
    let run_with = |dtype: &str, tag: &str| -> Vec<f32> {
        let dir = workdir(&format!("bf16grad-{tag}"));
        let mut cfg = tiny_cfg(8);
        cfg.training.zero_stage = 2;
        cfg.training.grad_dtype = dtype.into();
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let losses =
            out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        losses
    };
    let a = run_with("bf16", "a");
    let b = run_with("bf16", "b");
    let bits = |v: &[f32]| -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    };
    assert_eq!(bits(&a), bits(&b), "bf16 store is nondeterministic");
    let f = run_with("f32", "ref");
    for (i, (x, y)) in a.iter().zip(&f).enumerate() {
        assert!((x - y).abs() < 0.05,
                "step {i}: bf16 loss {x} far from f32 {y}");
    }
}
