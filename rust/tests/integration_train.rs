//! Integration: the full training stack — coordinator pipeline, the
//! multi-rank DP trainer over PJRT, real collectives, optimizer,
//! checkpoints. Uses the tiny variant to keep compile time small.

use txgain::config::{presets, Config};
use txgain::coordinator;
use txgain::runtime::Manifest;

fn artifacts() -> std::path::PathBuf {
    let dir = Manifest::default_dir();
    Manifest::load(&dir).expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );
    dir
}

fn workdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("txgain-it-train-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tiny_cfg(steps: usize) -> Config {
    let mut cfg = presets::quickstart();
    cfg.training.steps = steps;
    cfg.data.corpus_samples = 512;
    cfg
}

#[test]
fn loss_decreases_over_training() {
    let dir = workdir("loss");
    let mut cfg = tiny_cfg(50);
    cfg.training.lr = 1e-3; // tiny model: push hard so 50 steps show it
    cfg.training.warmup_steps = 5; // don't spend the test warming up
    let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    let r = &out.report;
    assert_eq!(r.records.len(), 50);
    let first = r.first_loss().unwrap();
    let tail = r.tail_loss(5).unwrap();
    assert!(
        tail < first - 0.5,
        "loss did not fall: {first} -> {tail}"
    );
    // report files exist and parse
    let json = std::fs::read_to_string(dir.join("report.json")).unwrap();
    let v = txgain::util::json::Value::parse(&json).unwrap();
    assert_eq!(v.req("steps").unwrap().as_usize().unwrap(), 50);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn ring_and_tree_allreduce_agree_exactly() {
    // the trajectory is a pure function of the config modulo the
    // collective algorithm — both must produce identical losses
    let run_with = |algo: &str| -> Vec<f32> {
        let dir = workdir(&format!("algo-{algo}"));
        let mut cfg = tiny_cfg(6);
        cfg.training.allreduce = algo.into();
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let losses =
            out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        losses
    };
    let ring = run_with("ring");
    let tree = run_with("tree");
    // identical schedules & data; fp reduction order differs between
    // algorithms, so allow tiny drift but require near-exact agreement
    assert_eq!(ring.len(), tree.len());
    for (a, b) in ring.iter().zip(&tree) {
        assert!((a - b).abs() < 5e-4, "ring {a} vs tree {b}");
    }
}

#[test]
fn bucketed_overlap_matches_monolithic_allreduce() {
    // the quickstart preset's 0.05 MB bucket splits the tiny model's
    // gradient into several buckets; the trajectory must match the
    // monolithic (overlap off) run — fp accumulation order inside the
    // collective differs with the buffer split, so allow the same tiny
    // drift the ring-vs-tree test does (bit-exactness of the bucketed
    // collective itself is asserted in collectives::bucket's tests)
    let run_with = |overlap: bool| -> Vec<f32> {
        let dir = workdir(&format!("overlap-{overlap}"));
        let mut cfg = tiny_cfg(6);
        cfg.training.overlap_comm = overlap;
        // isolate the overlap knob: quickstart defaults to zero_stage 1,
        // which (validly) refuses to run without overlap
        cfg.training.zero_stage = 0;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let losses =
            out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        losses
    };
    let bucketed = run_with(true);
    let mono = run_with(false);
    assert_eq!(bucketed.len(), mono.len());
    for (a, b) in bucketed.iter().zip(&mono) {
        assert!((a - b).abs() < 5e-4, "bucketed {a} vs monolithic {b}");
    }
}

#[test]
fn zero1_matches_replicated_trajectory_exactly() {
    // quickstart runs zero_stage 1 (reduce-scatter → shard step →
    // all-gather). Because ring all-reduce IS reduce-scatter +
    // all-gather, the reduced value every rank sees per element is
    // computed once on its owner either way — so the sharded run must
    // reproduce the replicated trajectory BIT-identically, not just
    // approximately (the artifact-free property test covers worlds
    // {1,2,4,8}; this covers the full PJRT stack).
    let run_with = |stage: usize| -> Vec<f32> {
        let dir = workdir(&format!("zero-{stage}"));
        let mut cfg = tiny_cfg(6);
        cfg.training.zero_stage = stage;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let losses =
            out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        losses
    };
    assert_eq!(run_with(1), run_with(0));
}

#[test]
fn every_transport_backend_trains_bit_identically() {
    // the transport moves bytes, the math never changes: the full
    // pipeline (ZeRO-1 quickstart: bucketed RS → shard step → AG →
    // sharded checkpointless run) must produce the exact same loss
    // trajectory on channel mailboxes, shm slot rings and tcp sockets
    let run_with = |transport: &str| -> Vec<f32> {
        let dir = workdir(&format!("tp-{transport}"));
        let mut cfg = tiny_cfg(4);
        cfg.training.transport = transport.into();
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let losses =
            out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        losses
    };
    let channel = run_with("channel");
    assert_eq!(channel.len(), 4);
    for t in ["shm", "tcp"] {
        assert_eq!(run_with(t), channel,
                   "transport {t} changed the trajectory");
    }
}

#[test]
fn world_size_one_also_trains() {
    let dir = workdir("solo");
    let mut cfg = tiny_cfg(5);
    cfg.cluster.nodes = 1;
    let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    assert_eq!(out.report.world, 1);
    assert_eq!(out.report.records.len(), 5);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn checkpoints_are_written_and_loadable() {
    let dir = workdir("ckpt");
    let mut cfg = tiny_cfg(6);
    cfg.training.checkpoint_every = 3;
    coordinator::run(&cfg, &artifacts(), &dir).unwrap();
    let ck3 = dir.join("checkpoints/step-000003.ckpt");
    let ck6 = dir.join("checkpoints/step-000006.ckpt");
    assert!(ck3.exists() && ck6.exists());
    let ck = txgain::train::checkpoint::load(&ck6).unwrap();
    assert_eq!(ck.step, 6);
    assert_eq!(ck.params.total_len() as u64,
               presets::model_tiny().param_count());
    assert!(ck.m.iter().any(|&x| x != 0.0), "optimizer state empty");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn network_direct_staging_also_works() {
    // functional equivalence of the two staging policies (perf differs,
    // numerics must not)
    let run_with = |policy| -> Vec<f32> {
        let dir = workdir(&format!("stag-{policy:?}"));
        let mut cfg = tiny_cfg(4);
        cfg.data.staging = policy;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let l = out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        l
    };
    use txgain::config::StagingPolicy as SP;
    assert_eq!(run_with(SP::LocalCopy), run_with(SP::NetworkDirect));
}

#[test]
fn loader_count_does_not_change_numerics() {
    let run_with = |loaders: usize| -> Vec<f32> {
        let dir = workdir(&format!("ld-{loaders}"));
        let mut cfg = tiny_cfg(4);
        cfg.data.loaders_per_gpu = loaders;
        let out = coordinator::run(&cfg, &artifacts(), &dir).unwrap();
        let l = out.report.records.iter().map(|r| r.loss).collect();
        std::fs::remove_dir_all(&dir).unwrap();
        l
    };
    assert_eq!(run_with(1), run_with(4));
}
