//! Integration: ZeRO-1 sharded optimizer states vs the replicated
//! (ZeRO-0) baseline, over the real in-process collectives — no AOT
//! artifacts needed (gradients are synthetic, exact-in-f32 values).
//!
//! The tentpole property: reduce-scatter → shard step → all-gather
//! must produce parameters BIT-IDENTICAL to "all-reduce, every rank
//! steps everything" when the reduced values are exact in f32, across
//! world sizes {1, 2, 4, 8}, uneven shard boundaries, and multiple
//! bucket sizes. AdamW's sqrt/divide are not exact, but they are the
//! same ops on the same inputs on both paths — so any divergence means
//! the sharding machinery (ownership map, moment cursor, gather) is
//! wrong.

use txgain::collectives::{allreduce, bucketed_all_gather,
                          bucketed_reduce_scatter, reduce_scatter,
                          Algorithm, BucketPlan, GradDtype, RankMemory,
                          World};
use txgain::config::presets;
use txgain::config::TrainingConfig;
use txgain::runtime::{HostParams, InitKind, ParamSpec, VariantMeta};
use txgain::train::checkpoint;
use txgain::train::{AdamW, GradResidency, ShardGrads};

/// A toy model whose tensor boundaries deliberately misalign with
/// shard and bucket boundaries: 2-D (decayed) and 1-D (undecayed)
/// tensors of awkward sizes.
fn toy_meta(n: usize) -> VariantMeta {
    assert!(n >= 12);
    let cut1 = n / 2 + 1; // odd-ish split inside the flat vector
    let cut2 = n - 5;
    VariantMeta {
        name: "zero-toy".into(),
        artifact: None,
        params: vec![
            ParamSpec { name: "w0".into(), shape: vec![1, cut1],
                        init: InitKind::Normal(0.02), offset: 0,
                        size: cut1 },
            ParamSpec { name: "b0".into(), shape: vec![cut2 - cut1],
                        init: InitKind::Zeros, offset: cut1,
                        size: cut2 - cut1 },
            ParamSpec { name: "w1".into(), shape: vec![5, 1],
                        init: InitKind::Normal(0.02), offset: cut2,
                        size: n - cut2 },
        ],
        grad_len: n,
        batch: 1,
        seq: 8,
        vocab: 16,
        hidden: 2,
        layers: 1,
        heads: 1,
        param_count: n as u64,
    }
}

fn toy_params(n: usize) -> HostParams {
    let meta = toy_meta(n);
    HostParams {
        tensors: meta
            .params
            .iter()
            .map(|p| {
                (0..p.size)
                    .map(|i| ((p.offset + i) % 7) as f32 * 0.25 - 0.75)
                    .collect()
            })
            .collect(),
    }
}

fn train_cfg() -> TrainingConfig {
    presets::quickstart().training
}

/// Per-rank gradient for `step`: dyadic rationals in [-2, 2] whose
/// sums over ≤8 ranks and division by a power-of-two world size stay
/// exact in f32.
fn grad(rank: usize, step: usize, n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| ((rank * 31 + i * 7 + step * 13) % 17) as f32 * 0.25
            - 2.0)
        .collect()
}

/// ZeRO-0 reference: exact summed-and-averaged gradients, one
/// replicated optimizer stepping everything.
fn run_replicated(world: usize, n: usize, steps: usize) -> HostParams {
    let meta = toy_meta(n);
    let mut params = toy_params(n);
    let mut opt = AdamW::new(&train_cfg(), n);
    for s in 0..steps {
        let mut g = vec![0.0f32; n];
        for r in 0..world {
            for (acc, v) in g.iter_mut().zip(grad(r, s, n)) {
                *acc += v;
            }
        }
        let inv = 1.0 / world as f32;
        for x in &mut g {
            *x *= inv;
        }
        opt.step(&mut params, &meta, &g, 1e-3);
    }
    params
}

/// ZeRO-1 over the real collectives: every rank reduce-scatters its
/// gradient buckets, steps only its shard, all-gathers the updated
/// parameters. Returns each rank's final replica.
fn run_sharded(algo: Algorithm, world: usize, n: usize, steps: usize,
               bucket_elems: usize) -> Vec<HostParams> {
    let meta = toy_meta(n);
    let plan = BucketPlan::from_elems(n, bucket_elems);
    std::thread::scope(|scope| {
        World::new(world)
            .into_comms()
            .into_iter()
            .enumerate()
            .map(|(rank, mut comm)| {
                let meta = meta.clone();
                let plan = plan.clone();
                scope.spawn(move || {
                    let mut params = toy_params(n);
                    let mut opt = AdamW::sharded(
                        &train_cfg(), plan.rank_ranges(rank, world));
                    let mut flat = vec![0.0f32; n];
                    for s in 0..steps {
                        let mut g = grad(rank, s, n);
                        let inv = 1.0 / world as f32;
                        for x in &mut g {
                            *x *= inv;
                        }
                        bucketed_reduce_scatter(algo, &mut comm, &mut g,
                                                &plan)
                            .unwrap();
                        opt.step(&mut params, &meta, &g, 1e-3);
                        params.flatten_into(&mut flat);
                        bucketed_all_gather(algo, &mut comm, &mut flat,
                                            &plan)
                            .unwrap();
                        params.unflatten_from(&flat);
                    }
                    params
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    })
}

/// ZeRO-2 over the real collectives: the trainer's free-on-reduce
/// schedule — per bucket (tail-first ready order) stage a copy,
/// truncate the backward source, reduce-scatter the copy, keep only
/// the owned shard in a [`ShardGrads`] store at `dtype` width, then
/// step AdamW straight from the shard-resident values and all-gather
/// the updated parameters. `grad_scale` lets tests choose dyadic
/// (exact) or non-dyadic (rounding-exercising) gradients. Returns each
/// rank's final replica.
fn run_zero2(algo: Algorithm, world: usize, n: usize, steps: usize,
             bucket_elems: usize, dtype: GradDtype, grad_scale: f32)
             -> Vec<HostParams> {
    let meta = toy_meta(n);
    let plan = BucketPlan::from_elems(n, bucket_elems);
    std::thread::scope(|scope| {
        World::new(world)
            .into_comms()
            .into_iter()
            .enumerate()
            .map(|(rank, mut comm)| {
                let meta = meta.clone();
                let plan = plan.clone();
                scope.spawn(move || {
                    let mut params = toy_params(n);
                    let mut opt = AdamW::sharded(
                        &train_cfg(), plan.rank_ranges(rank, world));
                    let mut shard =
                        ShardGrads::new(&plan, rank, world, dtype);
                    let mut flat = vec![0.0f32; n];
                    let mut window: Vec<f32> = Vec::new();
                    for s in 0..steps {
                        let mut g = grad(rank, s, n);
                        let inv = grad_scale / world as f32;
                        for x in &mut g {
                            *x *= inv;
                        }
                        for i in plan.ready_order() {
                            let (a, b) = plan.span(i);
                            window.clear();
                            window.extend_from_slice(&g[a..b]);
                            g.truncate(a);
                            reduce_scatter(algo, &mut comm,
                                           &mut window)
                                .unwrap();
                            let (sa, sb) =
                                plan.shard_span(i, rank, world);
                            shard.store_bucket(
                                i, &window[sa - a..sb - a]);
                        }
                        opt.tick();
                        for i in plan.ready_order() {
                            opt.step_span_with(&mut params, &meta,
                                               1e-3, plan.span(i),
                                               shard.bucket_reader(i));
                        }
                        params.flatten_into(&mut flat);
                        bucketed_all_gather(algo, &mut comm, &mut flat,
                                            &plan)
                            .unwrap();
                        params.unflatten_from(&flat);
                    }
                    params
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    })
}

fn assert_bit_identical(a: &HostParams, b: &HostParams, ctx: &str) {
    for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
        for (x, y) in ta.iter().zip(tb) {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{ctx}: {x} != {y} (bitwise)");
        }
    }
}

/// THE acceptance property.
#[test]
fn zero1_is_bit_identical_to_replicated_adamw() {
    let steps = 4;
    for algo in [Algorithm::Ring, Algorithm::Tree] {
        for world in [1usize, 2, 4, 8] {
            // n chosen so world and bucket sizes rarely divide it:
            // shard boundaries cut through tensors and buckets
            for n in [13usize, 29, 64] {
                for bucket_elems in [3usize, 7, n / 2 + 1, n, 2 * n] {
                    let reference = run_replicated(world, n, steps);
                    let sharded =
                        run_sharded(algo, world, n, steps, bucket_elems);
                    for (rank, p) in sharded.iter().enumerate() {
                        assert_bit_identical(
                            &reference, p,
                            &format!("{algo:?} world={world} n={n} \
                                      bucket={bucket_elems} rank={rank}"),
                        );
                    }
                }
            }
        }
    }
}

/// Per-rank optimizer state really shrinks ~1/N: the shards partition
/// the moment vector, no rank holds more than ceil(fair share) per
/// bucket.
#[test]
fn sharded_moments_partition_the_state() {
    let n = 1000usize;
    for world in [2usize, 4, 8] {
        let plan = BucketPlan::from_elems(n, 128);
        let mut total = 0usize;
        for rank in 0..world {
            let opt = AdamW::sharded(&train_cfg(),
                                     plan.rank_ranges(rank, world));
            let owned = opt.owned_len();
            total += owned;
            // fair share ± one element per bucket
            let fair = n / world;
            assert!(owned <= fair + plan.n_buckets(),
                    "world={world} rank={rank}: {owned} elems");
        }
        assert_eq!(total, n);
    }
}

/// Sharded checkpoint round-trip across world sizes: save the merged
/// file from a world-4 sharded run mid-training, resume both sharded
/// at world 2/8 (fresh shard extraction) and replicated — all must
/// continue bit-identically.
#[test]
fn sharded_checkpoint_resumes_across_world_sizes() {
    let n = 41usize;
    let steps_before = 3;
    let steps_after = 2;
    let meta = toy_meta(n);
    let plan = BucketPlan::from_elems(n, 10);
    let save_world = 4usize;

    // run world-4 sharded to the checkpoint, gather merged m/v
    let dir = std::env::temp_dir().join(format!(
        "txgain-it-zero-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("mid.ckpt");
    {
        let plan = plan.clone();
        let meta = meta.clone();
        let path = path.clone();
        std::thread::scope(|scope| {
            for (rank, mut comm) in World::new(save_world)
                .into_comms()
                .into_iter()
                .enumerate()
            {
                let meta = meta.clone();
                let plan = plan.clone();
                let path = path.clone();
                scope.spawn(move || {
                    let mut params = toy_params(n);
                    let mut opt = AdamW::sharded(
                        &train_cfg(),
                        plan.rank_ranges(rank, save_world));
                    let mut flat = vec![0.0f32; n];
                    for s in 0..steps_before {
                        let mut g = grad(rank, s, n);
                        for x in &mut g {
                            *x *= 1.0 / save_world as f32;
                        }
                        bucketed_reduce_scatter(Algorithm::Ring,
                                                &mut comm, &mut g,
                                                &plan)
                            .unwrap();
                        opt.step(&mut params, &meta, &g, 1e-3);
                        params.flatten_into(&mut flat);
                        bucketed_all_gather(Algorithm::Ring, &mut comm,
                                            &mut flat, &plan)
                            .unwrap();
                        params.unflatten_from(&flat);
                    }
                    let (s, m, v) = opt.state();
                    // a mid-epoch cursor rides along: step s of a
                    // notional epoch 0
                    let progress =
                        checkpoint::TrainProgress::new(s, 0, s);
                    checkpoint::save_sharded(&path, &mut comm, &plan,
                                             progress, &params, m, v)
                        .unwrap();
                });
            }
        });
    }

    let ck = checkpoint::load(&path).unwrap();
    assert_eq!(ck.step(), steps_before as u64);
    assert_eq!(ck.progress.epoch_step, steps_before as u64);

    // replicated continuation from the merged checkpoint = reference.
    // resume under a DIFFERENT world size (2 and 8): both sharded
    // continuations must match it bit-for-bit. (A different world also
    // changes the gradient average, so fix the "data" to the resumed
    // world's ranks for all three runs.)
    for resume_world in [2usize, 8] {
        let mut ref_params = ck.params.clone();
        let mut ref_opt = AdamW::new(&train_cfg(), n);
        ref_opt.restore(ck.step(), ck.m.clone(), ck.v.clone());
        for s in 0..steps_after {
            let mut g = vec![0.0f32; n];
            for r in 0..resume_world {
                for (acc, v) in
                    g.iter_mut().zip(grad(r, steps_before + s, n))
                {
                    *acc += v;
                }
            }
            for x in &mut g {
                *x *= 1.0 / resume_world as f32;
            }
            ref_opt.step(&mut ref_params, &meta, &g, 1e-3);
        }

        let resumed: Vec<HostParams> = std::thread::scope(|scope| {
            World::new(resume_world)
                .into_comms()
                .into_iter()
                .enumerate()
                .map(|(rank, mut comm)| {
                    let meta = meta.clone();
                    let plan = plan.clone();
                    let (ck_params, ck_m, ck_v, ck_step) =
                        (ck.params.clone(), ck.m.clone(), ck.v.clone(),
                         ck.step());
                    scope.spawn(move || {
                        let ranges =
                            plan.rank_ranges(rank, resume_world);
                        let mut params = ck_params;
                        let mut opt = AdamW::sharded(&train_cfg(),
                                                     ranges.clone());
                        opt.restore(
                            ck_step,
                            checkpoint::extract_shard(&ck_m, &ranges)
                                .unwrap(),
                            checkpoint::extract_shard(&ck_v, &ranges)
                                .unwrap(),
                        );
                        let mut flat = vec![0.0f32; n];
                        for s in 0..steps_after {
                            let mut g =
                                grad(rank, steps_before + s, n);
                            for x in &mut g {
                                *x *= 1.0 / resume_world as f32;
                            }
                            bucketed_reduce_scatter(Algorithm::Ring,
                                                    &mut comm, &mut g,
                                                    &plan)
                                .unwrap();
                            opt.step(&mut params, &meta, &g, 1e-3);
                            params.flatten_into(&mut flat);
                            bucketed_all_gather(Algorithm::Ring,
                                                &mut comm, &mut flat,
                                                &plan)
                                .unwrap();
                            params.unflatten_from(&flat);
                        }
                        params
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for (rank, p) in resumed.iter().enumerate() {
            assert_bit_identical(
                &ref_params, p,
                &format!("resume world={resume_world} rank={rank}"),
            );
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Loss averaging still uses a plain all-reduce under ZeRO — sanity
/// that mixing RS/AG and all-reduce on one comm stays FIFO-correct.
#[test]
fn mixed_collectives_on_one_comm_stay_consistent() {
    let world = 4usize;
    let n = 24usize;
    let plan = BucketPlan::from_elems(n, 7);
    let out: Vec<(Vec<f32>, f32)> = std::thread::scope(|scope| {
        World::new(world)
            .into_comms()
            .into_iter()
            .enumerate()
            .map(|(rank, mut comm)| {
                let plan = plan.clone();
                scope.spawn(move || {
                    let mut g: Vec<f32> =
                        (0..n).map(|i| (rank + i) as f32).collect();
                    bucketed_reduce_scatter(Algorithm::Ring, &mut comm,
                                            &mut g, &plan)
                        .unwrap();
                    let mut loss = [rank as f32 + 1.0];
                    allreduce(Algorithm::Ring, &mut comm, &mut loss)
                        .unwrap();
                    let mut flat: Vec<f32> = vec![0.0; n];
                    for &(a, b) in &plan.rank_ranges(rank, world) {
                        flat[a..b].copy_from_slice(&g[a..b]);
                    }
                    bucketed_all_gather(Algorithm::Ring, &mut comm,
                                        &mut flat, &plan)
                        .unwrap();
                    (flat, loss[0])
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    let want: Vec<f32> = (0..n)
        .map(|i| (0..world).map(|r| (r + i) as f32).sum())
        .collect();
    for (flat, loss) in &out {
        assert_eq!(flat, &want);
        assert_eq!(*loss, 1.0 + 2.0 + 3.0 + 4.0);
    }
}

/// THE stage-2 acceptance property: free-on-reduce with a
/// shard-resident f32 gradient store must reproduce the replicated
/// (ZeRO-0) trajectory bit for bit — same worlds, algorithms and
/// uneven bucket/shard geometries as the stage-1 property.
#[test]
fn zero2_free_on_reduce_is_bit_identical_to_replicated() {
    let steps = 4;
    for algo in [Algorithm::Ring, Algorithm::Tree] {
        for world in [1usize, 2, 4, 8] {
            for n in [13usize, 29, 64] {
                for bucket_elems in [3usize, 7, n / 2 + 1, n] {
                    let reference = run_replicated(world, n, steps);
                    let sharded = run_zero2(algo, world, n, steps,
                                            bucket_elems,
                                            GradDtype::F32, 1.0);
                    for (rank, p) in sharded.iter().enumerate() {
                        assert_bit_identical(
                            &reference, p,
                            &format!("zero2 {algo:?} world={world} \
                                      n={n} bucket={bucket_elems} \
                                      rank={rank}"),
                        );
                    }
                }
            }
        }
    }
}

/// The bf16 gradient store rounds with the wire's RNE — and on dyadic
/// gradients (every reduced value is exactly bf16-representable) the
/// rounding is the identity, so zero2+bf16 must still match the f32
/// replicated reference bit for bit. This pins the "host storage and
/// the bf16 wire round identically" contract.
#[test]
fn bf16_shard_store_is_exact_on_dyadic_gradients() {
    let steps = 4;
    for world in [1usize, 2, 4] {
        for bucket_elems in [7usize, 29] {
            let n = 29usize;
            let reference = run_replicated(world, n, steps);
            let sharded = run_zero2(Algorithm::Ring, world, n, steps,
                                    bucket_elems, GradDtype::Bf16, 1.0);
            for (rank, p) in sharded.iter().enumerate() {
                assert_bit_identical(
                    &reference, p,
                    &format!("bf16-dyadic world={world} \
                              bucket={bucket_elems} rank={rank}"),
                );
            }
        }
    }
}

/// With non-dyadic gradients (scale 1/3) the bf16 store genuinely
/// rounds. The contract is then: deterministic (two runs agree bit for
/// bit), replica-identical (every rank ends with the same params —
/// each element's update is computed once, on its owner, from the
/// owner's stored value), and bounded against the f32 store.
#[test]
fn bf16_shard_store_is_deterministic_replica_identical_and_bounded() {
    let world = 4usize;
    let n = 29usize;
    let steps = 4;
    let scale = 1.0f32 / 3.0;
    let a = run_zero2(Algorithm::Ring, world, n, steps, 7,
                      GradDtype::Bf16, scale);
    let b = run_zero2(Algorithm::Ring, world, n, steps, 7,
                      GradDtype::Bf16, scale);
    for (rank, (pa, pb)) in a.iter().zip(&b).enumerate() {
        assert_bit_identical(pa, pb,
                             &format!("bf16 determinism rank={rank}"));
    }
    for (rank, p) in a.iter().enumerate().skip(1) {
        assert_bit_identical(&a[0], p,
                             &format!("bf16 replica rank={rank}"));
    }
    // bounded: bf16 keeps 8 significant bits, AdamW normalizes the
    // update to ~lr per element — after 4 steps at lr 1e-3 the two
    // trajectories can only be a few updates' rounding apart
    let f = run_zero2(Algorithm::Ring, world, n, steps, 7,
                      GradDtype::F32, scale);
    for (tb, tf) in a[0].tensors.iter().zip(&f[0].tensors) {
        for (x, y) in tb.iter().zip(tf) {
            assert!((x - y).abs() < 2e-2,
                    "bf16 {x} vs f32 {y} drifted past the bound");
        }
    }
}

/// Satellite 3: the measured gradient-plane peak of the free-on-reduce
/// schedule equals the closed-form `RankMemory::grad_peak_bytes` on
/// every rank — across worlds {2,4,8}, bucket sizes, uneven shard
/// boundaries (prime n, uneven first bucket) and both storage dtypes.
#[test]
fn measured_grad_peak_matches_the_closed_form() {
    let n = 97usize;
    let plans = [
        BucketPlan::from_elems(n, 7),
        BucketPlan::from_elems(n, 13),
        BucketPlan::from_elems_with_first(n, 13, 5),
    ];
    for world in [2usize, 4, 8] {
        for plan in &plans {
            for dtype in GradDtype::ALL {
                let peaks: Vec<u64> = std::thread::scope(|scope| {
                    World::new(world)
                        .into_comms()
                        .into_iter()
                        .enumerate()
                        .map(|(rank, mut comm)| {
                            let plan = plan.clone();
                            scope.spawn(move || {
                                let mut res = GradResidency::new();
                                let mut shard = ShardGrads::new(
                                    &plan, rank, world, dtype);
                                let mut g = grad(rank, 0, n);
                                let mut window: Vec<f32> = Vec::new();
                                for i in plan.ready_order() {
                                    let (a, b) = plan.span(i);
                                    window.clear();
                                    window.extend_from_slice(&g[a..b]);
                                    res.alloc(4 * (b - a) as u64);
                                    g.truncate(a);
                                    reduce_scatter(Algorithm::Ring,
                                                   &mut comm,
                                                   &mut window)
                                        .unwrap();
                                    let (sa, sb) = plan
                                        .shard_span(i, rank, world);
                                    shard.store_bucket(
                                        i, &window[sa - a..sb - a]);
                                    res.alloc(shard.span_bytes(i));
                                    res.free(4 * (b - a) as u64);
                                }
                                res.peak()
                            })
                        })
                        .collect::<Vec<_>>()
                        .into_iter()
                        .map(|h| h.join().unwrap())
                        .collect()
                });
                for (rank, &peak) in peaks.iter().enumerate() {
                    let want = RankMemory::grad_peak_bytes(
                        Some(plan), n, rank, world, 2, dtype, false);
                    assert_eq!(peak, want,
                               "world={world} rank={rank} {dtype} \
                                buckets={}: measured {peak} != \
                                closed form {want}",
                               plan.n_buckets());
                }
            }
        }
    }
}
