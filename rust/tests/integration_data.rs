//! Integration: the streaming, memory-bounded data plane — no AOT
//! artifacts needed.
//!
//! The tentpole property: the streaming loader (header-only
//! `DatasetIndex`, byte-budgeted `BlockCache`, lazy windowed-shuffle
//! cursor) must deliver batches BIT-IDENTICAL to the in-memory
//! reference path (whole corpus resident, materialized order) — across
//! worker counts, cache sizes (down to a single resident block), world
//! sizes and shuffle windows, and from any mid-epoch resume point.
//! Residency is a performance knob; it must never be a numerics knob.

use std::path::PathBuf;
use std::sync::Arc;

use txgain::data::{
    BlockCache, DatasetIndex, HostBatch, LoaderPool, Masker, Sample,
    ShardWriter, WindowedPlan,
};

const SEQ: usize = 32;

fn workdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("txgain-it-data-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic multi-shard corpus with deliberately uneven shard
/// sizes; returns (shard paths, all samples in global-id order).
fn write_corpus(dir: &PathBuf, counts: &[usize])
    -> (Vec<PathBuf>, Vec<Sample>) {
    let mut paths = Vec::new();
    let mut all = Vec::new();
    let mut id = 0u16;
    for (si, &n) in counts.iter().enumerate() {
        let p = dir.join(format!("shard-{si:03}.bin"));
        let mut w = ShardWriter::create(&p, SEQ).unwrap();
        for _ in 0..n {
            // distinct, id-tagged content so any index mix-up changes
            // bits somewhere
            let toks: Vec<u16> = (0..SEQ - 3)
                .map(|j| 4 + ((id as usize * 31 + j * 7) % 400) as u16)
                .collect();
            let s = Sample::from_tokens(&toks, SEQ);
            w.write(&s).unwrap();
            all.push(s);
            id = id.wrapping_add(1);
        }
        w.finish().unwrap();
        paths.push(p);
    }
    (paths, all)
}

fn drain(pool: &mut LoaderPool) -> Vec<HostBatch> {
    let mut out = Vec::new();
    while let Some(b) = pool.next_batch() {
        out.push(b);
    }
    assert!(pool.take_error().is_none(), "loader died");
    out
}

fn assert_batches_eq(a: &[HostBatch], b: &[HostBatch], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: batch count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.step, y.step, "{ctx}");
        assert_eq!(x.input_ids, y.input_ids, "{ctx} step {}", x.step);
        assert_eq!(x.labels, y.labels, "{ctx} step {}", x.step);
        let xm: Vec<u32> =
            x.attn_mask.iter().map(|v| v.to_bits()).collect();
        let ym: Vec<u32> =
            y.attn_mask.iter().map(|v| v.to_bits()).collect();
        assert_eq!(xm, ym, "{ctx} step {}", x.step);
    }
}

#[test]
fn streaming_matches_in_memory_reference_bit_for_bit() {
    let dir = workdir("equiv");
    let counts = [50usize, 37, 63]; // 150 samples, uneven shards
    let (paths, samples) = write_corpus(&dir, &counts);
    let index = Arc::new(DatasetIndex::open(&paths).unwrap());
    let dataset = Arc::new(samples);
    let masker = Masker::new(0.15, 512);
    let seed = 11u64;
    let batch = 5usize;
    let shard_counts = index.shard_counts();

    for world in [1usize, 2, 3] {
        for window in [1usize, 32, 1 << 20] {
            let plan = Arc::new(
                WindowedPlan::build(&shard_counts, world, 1, seed,
                                    window)
                    .unwrap());
            for rank in 0..world {
                // reference: resident Vec + materialized order
                let order = plan.materialize_rank(rank);
                let mut reference = LoaderPool::spawn(
                    dataset.clone(), SEQ, &order, batch,
                    masker.clone(), seed, 1, 2, 2, 0)
                    .unwrap();
                let want = drain(&mut reference);
                // streaming: every (workers × cache) combination must
                // reproduce it exactly, including a one-block cache
                for workers in [1usize, 4] {
                    for cache_mb in [0.003f64, 64.0] {
                        let cache = Arc::new(BlockCache::new(
                            index.clone(), cache_mb).unwrap());
                        let mut pool = LoaderPool::spawn_streaming(
                            cache, plan.clone(), rank, batch,
                            masker.clone(), seed, workers, 2, 0, 0)
                            .unwrap();
                        let got = drain(&mut pool);
                        assert_batches_eq(&want, &got, &format!(
                            "world={world} rank={rank} window={window} \
                             workers={workers} cache={cache_mb}"));
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn mid_epoch_resume_continues_the_stream_bit_for_bit() {
    let dir = workdir("resume");
    let (paths, _) = write_corpus(&dir, &[80, 45]);
    let index = Arc::new(DatasetIndex::open(&paths).unwrap());
    let masker = Masker::new(0.15, 512);
    let batch = 5usize;
    let plan = Arc::new(
        WindowedPlan::build(&index.shard_counts(), 2, 3, 7, 16)
            .unwrap());
    let cache = Arc::new(BlockCache::new(index.clone(), 64.0).unwrap());

    for rank in 0..2 {
        let mut full = LoaderPool::spawn_streaming(
            cache.clone(), plan.clone(), rank, batch, masker.clone(),
            7, 3, 2, 0, 0)
            .unwrap();
        let all = drain(&mut full);
        for start in [1usize, all.len() / 2, all.len()] {
            // a fresh cold cache on resume: restarting a node loses
            // its cache, never its determinism
            let cold = Arc::new(
                BlockCache::new(index.clone(), 0.003).unwrap());
            let mut resumed = LoaderPool::spawn_streaming(
                cold, plan.clone(), rank, batch, masker.clone(), 7, 2,
                2, 0, start)
                .unwrap();
            assert_eq!(resumed.total_steps(), all.len() - start);
            let got = drain(&mut resumed);
            assert_batches_eq(&all[start..], &got,
                              &format!("rank={rank} start={start}"));
        }
    }
    // resuming past the epoch end is a clean error, not a hang
    assert!(LoaderPool::spawn_streaming(
        cache.clone(), plan.clone(), 0, batch, masker, 7, 1, 2, 0,
        9999)
        .is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resident_memory_stays_within_the_cache_budget() {
    // stream a corpus much larger than the budget through a full
    // epoch: the cache must never hold more than budget bytes (or one
    // block, whichever is larger) — this is the O(cache_mb) claim
    let dir = workdir("budget");
    let (paths, _) = write_corpus(&dir, &[300, 300, 300, 300]);
    let index = Arc::new(DatasetIndex::open(&paths).unwrap());
    let corpus_bytes = index.total_bytes();
    let budget_mb = 0.02f64; // ~21 KB vs ~79 KB of corpus
    let cache =
        Arc::new(BlockCache::new(index.clone(), budget_mb).unwrap());
    let plan = Arc::new(
        WindowedPlan::build(&index.shard_counts(), 1, 0, 9, 64)
            .unwrap());
    let mut pool = LoaderPool::spawn_streaming(
        cache.clone(), plan, 0, 10, Masker::new(0.15, 512), 9, 3, 2, 0,
        0)
        .unwrap();
    // blocks clamp to the shard tail: the largest real block is
    // min(block_samples, shard) samples
    let block_bytes = (cache.block_samples() as u64).min(300)
        * Sample::disk_bytes(SEQ);
    let ceiling =
        ((budget_mb * 1024.0 * 1024.0) as u64).max(block_bytes)
            + block_bytes; // one block of transient slack at insert
    while pool.next_batch().is_some() {
        assert!(cache.resident_bytes() <= ceiling,
                "resident {} exceeds ceiling {ceiling}",
                cache.resident_bytes());
    }
    assert!(pool.take_error().is_none());
    assert!(cache.resident_bytes() < corpus_bytes / 2,
            "cache ended up holding most of the corpus");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn shard_truncated_after_indexing_kills_the_loader_cleanly() {
    // the index was built against a healthy file; the file then loses
    // its tail (partial re-stage, disk fault). The loader must stop
    // with an error — not hang, not fabricate data.
    let dir = workdir("trunc");
    let (paths, _) = write_corpus(&dir, &[120]);
    let index = Arc::new(DatasetIndex::open(&paths).unwrap());
    let bytes = std::fs::read(&paths[0]).unwrap();
    std::fs::write(&paths[0], &bytes[..bytes.len() / 2]).unwrap();
    let cache = Arc::new(BlockCache::new(index.clone(), 1.0).unwrap());
    let plan = Arc::new(
        WindowedPlan::build(&index.shard_counts(), 1, 0, 5, 8)
            .unwrap());
    let mut pool = LoaderPool::spawn_streaming(
        cache, plan, 0, 8, Masker::new(0.15, 512), 5, 2, 2, 0, 0)
        .unwrap();
    while pool.next_batch().is_some() {}
    let err = pool.take_error().expect("loader must surface the fault");
    assert!(format!("{err:#}").contains("shard"),
            "unhelpful error: {err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn carried_remainder_leads_the_next_epoch_bit_for_bit() {
    // remainder roll-in (data-plane item (c)): the tail samples epoch
    // e leaves undelivered must open epoch e+1's stream — in epoch
    // e's own order — and nothing may be dropped or duplicated across
    // the pair. Geometry: 75/rank at batch 10 → carry walks
    // 0,5,0,5,… per epoch.
    let dir = workdir("carry");
    let (paths, samples) = write_corpus(&dir, &[80, 70]);
    let index = Arc::new(DatasetIndex::open(&paths).unwrap());
    let dataset = Arc::new(samples);
    let masker = Masker::new(0.15, 512);
    let batch = 10usize;
    let world = 2usize;
    let cache = Arc::new(BlockCache::new(index.clone(), 64.0).unwrap());
    let shard_counts = index.shard_counts();
    let build = |epoch: u64| -> Arc<WindowedPlan> {
        Arc::new(WindowedPlan::build(&shard_counts, world, epoch, 7, 16)
            .unwrap())
    };

    for rank in 0..world {
        let p0 = build(0);
        let p1 = build(1);
        assert_eq!(p0.carry_in(batch), 0);
        assert_eq!(p1.carry_in(batch), 5, "75 % 10 carried");
        assert_eq!(p1.steps_with_carry(batch), 8, "5 + 75 over 10");

        // epoch 0's last 5 sample ids (undelivered at batch 10)
        let order0 = p0.materialize_rank(rank);
        let tail: Vec<u32> = order0[order0.len() - 5..].to_vec();
        // epoch 1 with carry: first batch = tail ++ first 5 of its own
        let order1 = p1.materialize_rank(rank);
        let mut want_first: Vec<u32> = tail.clone();
        want_first.extend_from_slice(&order1[..5]);

        let mut pool = LoaderPool::spawn_streaming_carry(
            cache.clone(), p1.clone(), Some(p0.clone()), rank, batch,
            masker.clone(), 7, 3, 2, 0, 0, true)
            .unwrap();
        assert_eq!(pool.total_steps(), 8);
        let got = drain(&mut pool);
        // worker-count independence of the carried stream (and
        // prefetch-independence: this pool warms ahead, that one not)
        let mut pool1 = LoaderPool::spawn_streaming_carry(
            cache.clone(), p1.clone(), Some(p0.clone()), rank, batch,
            masker.clone(), 7, 1, 2, 0, 0, false)
            .unwrap();
        let got1 = drain(&mut pool1);
        assert_batches_eq(&got, &got1, &format!("rank={rank} workers"));

        // mid-epoch resume through a carried epoch
        let mut resumed = LoaderPool::spawn_streaming_carry(
            cache.clone(), p1.clone(), Some(p0.clone()), rank, batch,
            masker.clone(), 7, 2, 2, 0, 3, true)
            .unwrap();
        let tail_batches = drain(&mut resumed);
        assert_batches_eq(&got[3..], &tail_batches,
                          &format!("rank={rank} resume"));

        // the carried prefix really is epoch 0's tail: feed the
        // in-memory reference pool exactly those ids under epoch 1's
        // masking keys and compare the first carried batch
        let mut reference = LoaderPool::spawn(
            dataset.clone(), SEQ, &want_first, batch, masker.clone(), 7,
            p1.epoch, 1, 2, 0)
            .unwrap();
        let want = drain(&mut reference);
        assert_batches_eq(&want, &got[..1],
                          &format!("rank={rank} carried prefix"));

        // leftover accounting: this epoch leaves (5 + 75) % 10 = 0
        use std::sync::atomic::Ordering;
        assert_eq!(
            pool.stats.dropped_remainder.load(Ordering::Relaxed), 0);
    }

    // mismatched carry geometry is refused loudly
    let p0 = build(0);
    let p2 = build(2);
    let err = LoaderPool::spawn_streaming_carry(
        cache.clone(), p2, Some(p0), 0, batch, masker, 7, 1, 2, 0, 0,
        true)
        .unwrap_err()
        .to_string();
    assert!(err.contains("preceding epoch"), "unhelpful: {err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn prefetch_changes_no_bits_and_warms_ahead() {
    // data.prefetch is a latency knob, never a numerics knob: the same
    // stream with the warm-ahead thread on and off must be
    // bit-identical, and on it must actually warm blocks before the
    // demand path reaches them.
    use std::sync::atomic::Ordering;
    let dir = workdir("prefetch");
    let (paths, _) = write_corpus(&dir, &[90, 60]);
    let index = Arc::new(DatasetIndex::open(&paths).unwrap());
    let masker = Masker::new(0.15, 512);
    let batch = 6usize;
    let plan = Arc::new(
        WindowedPlan::build(&index.shard_counts(), 2, 1, 13, 16)
            .unwrap());
    for rank in 0..2 {
        let run = |warm: bool, delay_us: u64| {
            // fresh cold cache per run so each measures its own traffic
            let cache = Arc::new(
                BlockCache::new(index.clone(), 64.0).unwrap());
            let mut pool = LoaderPool::spawn_streaming_carry(
                cache, plan.clone(), None, rank, batch, masker.clone(),
                13, 2, 2, delay_us, 0, warm)
                .unwrap();
            let got = drain(&mut pool);
            let warmed =
                pool.stats.io.prefetched_blocks.load(Ordering::Relaxed);
            (got, warmed)
        };
        let (off, warmed_off) = run(false, 0);
        // slow workers (2 ms/batch) give the prefetcher a head start,
        // so it demonstrably wins the cold blocks
        let (on, warmed_on) = run(true, 2000);
        assert_batches_eq(&off, &on, &format!("rank={rank} prefetch"));
        assert_eq!(warmed_off, 0, "prefetch off must not warm blocks");
        assert!(warmed_on > 0, "prefetch on never warmed a block");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn worker_affinity_streaks_within_blocks() {
    // 128 samples in one shard, far smaller than one cache block: with
    // the run-based split every lookup past each worker's first lands
    // in that worker's previous block. 2 workers → exactly 126 of the
    // 128 lookups are affine.
    use std::sync::atomic::Ordering;
    let dir = workdir("affinity");
    let (paths, _) = write_corpus(&dir, &[128]);
    let index = Arc::new(DatasetIndex::open(&paths).unwrap());
    let cache = Arc::new(BlockCache::new(index.clone(), 64.0).unwrap());
    let plan = Arc::new(
        WindowedPlan::build(&index.shard_counts(), 1, 0, 3, 32)
            .unwrap());
    let mut pool = LoaderPool::spawn_streaming(
        cache, plan, 0, 8, Masker::new(0.15, 512), 3, 2, 2, 0, 0)
        .unwrap();
    assert_eq!(drain(&mut pool).len(), 16);
    assert_eq!(pool.stats.io.affine_hits.load(Ordering::Relaxed), 126);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn epochs_shuffle_differently_but_reproducibly() {
    let dir = workdir("epochs");
    let (paths, _) = write_corpus(&dir, &[64, 64]);
    let index = Arc::new(DatasetIndex::open(&paths).unwrap());
    let cache = Arc::new(BlockCache::new(index.clone(), 64.0).unwrap());
    let collect = |epoch: u64| -> Vec<i32> {
        let plan = Arc::new(
            WindowedPlan::build(&index.shard_counts(), 1, epoch, 5, 32)
                .unwrap());
        let mut pool = LoaderPool::spawn_streaming(
            cache.clone(), plan, 0, 8, Masker::new(0.15, 512), 5, 3, 2,
            0, 0)
            .unwrap();
        let mut all = Vec::new();
        while let Some(b) = pool.next_batch() {
            all.extend(b.input_ids);
        }
        all
    };
    let e0a = collect(0);
    let e0b = collect(0);
    let e1 = collect(1);
    assert_eq!(e0a, e0b, "same epoch must reproduce exactly");
    assert_ne!(e0a, e1, "different epochs must differ");
    std::fs::remove_dir_all(&dir).unwrap();
}
