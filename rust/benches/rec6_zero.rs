//! BENCH REC6-ZERO: the ZeRO sharded-state ablation behind the
//! `training.zero_stage` knob — stage 1 (sharded optimizer) and
//! stage 2 (sharded gradients, free-on-reduce).
//!
//! Part 1 sweeps world size through the analytic memory model and
//! shows the 1/N curves for every stage in `ZERO_STAGES` — the memory
//! that becomes micro-batch headroom (the paper's rec. 5 lever).
//! Part 2 prices the full step: reduce-scatter overlapped with
//! backward plus the exposed parameter all-gather, against the plain
//! overlapped all-reduce. Part 3 times the real sharded schedules
//! against the monolithic all-reduce on every transport backend —
//! stage 1 (in-place RS → shard step → AG) and stage 2 (free-on-reduce
//! staging copies + `ShardGrads` store, `GradResidency`-metered): same
//! wire bytes, so the sharding must cost ~nothing extra on any wire
//! while the stage-2 gradient-plane peak collapses toward 4·P/W.
//!
//! Flags: `--stage <n>` picks the sharded stage for parts 2/3
//! (default 2), `--grad-dtype f32|bf16` the stage-2 storage width
//! (default f32). `-- --smoke` runs the verify.sh gate instead:
//! at world 4 on shm, stage-2 measured peak gradient bytes must not
//! exceed stage-1, must equal `RankMemory::grad_peak_bytes` exactly,
//! and the f32 trajectory must be bit-identical to stage 1.
//!
//! Run: `cargo bench --bench rec6_zero`

use txgain::collectives::{allreduce, bucketed_all_gather,
                          bucketed_reduce_scatter, reduce_scatter,
                          Algorithm, Backend, BucketPlan, CostModel,
                          GradDtype, RankMemory};
use txgain::config::{presets, ZERO_STAGES};
use txgain::perfmodel::simulate;
use txgain::report::Table;
use txgain::train::{GradResidency, ShardGrads};
use txgain::util::bench::{bench, black_box, section};

/// `--stage <n>`: the sharded stage parts 2/3 compare against stage 0.
fn stage_from_args() -> usize {
    let args: Vec<String> = std::env::args().collect();
    match args.iter().position(|a| a == "--stage") {
        Some(i) => {
            let st: usize = args
                .get(i + 1)
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(|| {
                    panic!("--stage needs one of {ZERO_STAGES:?}")
                });
            assert!(ZERO_STAGES.contains(&st),
                    "--stage must be one of {ZERO_STAGES:?}, got {st}");
            st
        }
        None => 2,
    }
}

/// `--grad-dtype f32|bf16`: stage-2 gradient storage width.
fn grad_dtype_from_args() -> GradDtype {
    let args: Vec<String> = std::env::args().collect();
    GradDtype::from_flag(&args).unwrap().unwrap_or_default()
}

/// Stage 1 over the real wire: in-place bucketed reduce-scatter →
/// shard-local step → bucketed all-gather. Returns (wall secs, max
/// per-rank measured gradient-plane peak).
fn run_stage1(backend: Backend, world: usize, len: usize,
              plan: &BucketPlan) -> (f64, u64) {
    let t0 = std::time::Instant::now();
    let peaks: Vec<u64> = std::thread::scope(|s| {
        backend
            .world(world)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(rank, mut c)| {
                let plan = plan.clone();
                s.spawn(move || {
                    let mut res = GradResidency::new();
                    let mut buf = vec![1.0f32; len];
                    res.alloc(4 * len as u64);
                    bucketed_reduce_scatter(Algorithm::Ring, &mut c,
                                            &mut buf, &plan)
                        .unwrap();
                    for &(a, b) in &plan.rank_ranges(rank, world) {
                        for x in &mut buf[a..b] {
                            *x *= 0.5; // the "optimizer step"
                        }
                    }
                    res.free(4 * len as u64);
                    bucketed_all_gather(Algorithm::Ring, &mut c,
                                        &mut buf, &plan)
                        .unwrap();
                    black_box(buf[0]);
                    res.peak()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    (t0.elapsed().as_secs_f64(), peaks.into_iter().max().unwrap_or(0))
}

/// Stage 2 over the real wire: the trainer's free-on-reduce schedule —
/// per bucket stage a copy, truncate the source, reduce-scatter, keep
/// only the owned shard (at `dtype` width), release the staging copy;
/// then step the shard-resident values and all-gather the replicas.
fn run_stage2(backend: Backend, world: usize, len: usize,
              plan: &BucketPlan, dtype: GradDtype) -> (f64, u64) {
    let t0 = std::time::Instant::now();
    let peaks: Vec<u64> = std::thread::scope(|s| {
        backend
            .world(world)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(rank, mut c)| {
                let plan = plan.clone();
                s.spawn(move || {
                    let mut res = GradResidency::new();
                    let mut shard =
                        ShardGrads::new(&plan, rank, world, dtype);
                    let mut g = vec![1.0f32; len];
                    let mut window: Vec<f32> = Vec::new();
                    for i in plan.ready_order() {
                        let (a, b) = plan.span(i);
                        window.clear();
                        window.extend_from_slice(&g[a..b]);
                        res.alloc(4 * (b - a) as u64);
                        g.truncate(a);
                        reduce_scatter(Algorithm::Ring, &mut c,
                                       &mut window)
                            .unwrap();
                        let (sa, sb) = plan.shard_span(i, rank, world);
                        shard.store_bucket(i, &window[sa - a..sb - a]);
                        res.alloc(shard.span_bytes(i));
                        res.free(4 * (b - a) as u64);
                    }
                    let mut flat = vec![0.0f32; len];
                    for i in 0..plan.n_buckets() {
                        let (sa, sb) = plan.shard_span(i, rank, world);
                        let read = shard.bucket_reader(i);
                        for k in sa..sb {
                            flat[k] = 0.5 * read(k);
                        }
                    }
                    bucketed_all_gather(Algorithm::Ring, &mut c,
                                        &mut flat, &plan)
                        .unwrap();
                    black_box(flat[0]);
                    res.peak()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    (t0.elapsed().as_secs_f64(), peaks.into_iter().max().unwrap_or(0))
}

/// The verify.sh smoke gate: at world 4 on shm, the stage-2
/// free-on-reduce schedule must (a) keep measured peak gradient-plane
/// bytes at or below the stage-1 in-place sync, (b) reproduce the
/// closed-form `RankMemory::grad_peak_bytes` exactly on every rank,
/// and (c) leave the f32 trajectory bit-identical to stage 1. Dyadic
/// inputs keep every reduction exact in f32, so (c) is exact equality
/// of bits, not a tolerance. Panics (nonzero exit) on any violation.
fn smoke() {
    let world = 4usize;
    let len = 600_000usize;
    // uneven first + tail buckets: shard boundaries cut unevenly
    let plan =
        BucketPlan::from_elems_with_first(len, len / 5 + 3, len / 9 + 1);
    let seed = |rank: usize| -> Vec<f32> {
        (0..len)
            .map(|i| ((rank * 31 + i * 7) % 17) as f32 * 0.25 - 2.0)
            .collect()
    };
    // returns per-rank (measured peak, final replica) for one sync:
    // RS → double the owned shard → AG
    let run = |stage: usize, dtype: GradDtype| -> Vec<(u64, Vec<f32>)> {
        std::thread::scope(|s| {
            Backend::Shm
                .world(world)
                .unwrap()
                .into_iter()
                .enumerate()
                .map(|(rank, mut c)| {
                    let plan = plan.clone();
                    let seeded = seed(rank);
                    s.spawn(move || {
                        let mut res = GradResidency::new();
                        let mut flat = vec![0.0f32; len];
                        if stage >= 2 {
                            let mut shard = ShardGrads::new(
                                &plan, rank, world, dtype);
                            let mut g = seeded;
                            let mut window: Vec<f32> = Vec::new();
                            for i in plan.ready_order() {
                                let (a, b) = plan.span(i);
                                window.clear();
                                window.extend_from_slice(&g[a..b]);
                                res.alloc(4 * (b - a) as u64);
                                g.truncate(a);
                                reduce_scatter(Algorithm::Ring, &mut c,
                                               &mut window)
                                    .unwrap();
                                let (sa, sb) =
                                    plan.shard_span(i, rank, world);
                                shard.store_bucket(
                                    i, &window[sa - a..sb - a]);
                                res.alloc(shard.span_bytes(i));
                                res.free(4 * (b - a) as u64);
                            }
                            for i in 0..plan.n_buckets() {
                                let (sa, sb) =
                                    plan.shard_span(i, rank, world);
                                let read = shard.bucket_reader(i);
                                for k in sa..sb {
                                    flat[k] = 2.0 * read(k);
                                }
                            }
                        } else {
                            let mut g = seeded;
                            res.alloc(4 * len as u64);
                            bucketed_reduce_scatter(Algorithm::Ring,
                                                    &mut c, &mut g,
                                                    &plan)
                                .unwrap();
                            for i in 0..plan.n_buckets() {
                                let (sa, sb) =
                                    plan.shard_span(i, rank, world);
                                for k in sa..sb {
                                    flat[k] = 2.0 * g[k];
                                }
                            }
                            res.free(4 * len as u64);
                        }
                        bucketed_all_gather(Algorithm::Ring, &mut c,
                                            &mut flat, &plan)
                            .unwrap();
                        (res.peak(), flat)
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        })
    };
    let s1 = run(1, GradDtype::F32);
    let s2 = run(2, GradDtype::F32);
    let s2bf = run(2, GradDtype::Bf16);
    for rank in 0..world {
        for (dtype, got) in
            [(GradDtype::F32, &s2), (GradDtype::Bf16, &s2bf)]
        {
            let want = RankMemory::grad_peak_bytes(
                Some(&plan), len, rank, world, 2, dtype, false);
            assert_eq!(
                got[rank].0, want,
                "SMOKE FAIL: rank {rank} {dtype} measured peak {} != \
                 closed form {want}",
                got[rank].0
            );
        }
        assert!(
            s2[rank].0 <= s1[rank].0,
            "SMOKE FAIL: rank {rank} stage-2 peak {} > stage-1 peak {} \
             — free-on-reduce is not freeing",
            s2[rank].0, s1[rank].0
        );
        assert!(
            s2bf[rank].0 < s2[rank].0,
            "SMOKE FAIL: rank {rank} bf16 peak {} !< f32 peak {}",
            s2bf[rank].0, s2[rank].0
        );
        for (k, (x, y)) in
            s1[rank].1.iter().zip(&s2[rank].1).enumerate()
        {
            assert_eq!(
                x.to_bits(), y.to_bits(),
                "SMOKE FAIL: rank {rank} trajectory diverged at elem \
                 {k}: stage-1 {x} vs stage-2 {y}"
            );
        }
    }
    println!(
        "rec6 smoke [shm, world {world}, {len} floats, {} buckets]:\n  \
         stage-1 peak {:7.2} MB\n  stage-2 peak {:7.2} MB (f32, \
         closed-form exact)\n  stage-2 peak {:7.2} MB (bf16, \
         closed-form exact)",
        plan.n_buckets(), s1[0].0 as f64 / 1e6, s2[0].0 as f64 / 1e6,
        s2bf[0].0 as f64 / 1e6
    );
    println!("rec6 smoke: OK (free-on-reduce peak is {:.0}% of \
              stage-1, trajectory bit-identical)",
             s2[0].0 as f64 / s1[0].0.max(1) as f64 * 100.0);
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }
    let stage = stage_from_args();
    let dtype = grad_dtype_from_args();

    section("analytic: per-rank gradient + optimizer state vs world \
             size (1/N)");
    const WORLDS: [usize; 4] = [2, 8, 32, 256];
    let mut headers = vec!["model".to_string()];
    for st in ZERO_STAGES {
        if st == 0 {
            headers.push("stage-0".into());
        } else {
            headers.extend(WORLDS.iter().map(|w| format!("s{st} W={w}")));
        }
    }
    let mut t = Table::new(
        &format!("gradient + Adam m/v bytes per rank (MB), grad_dtype \
                  {dtype}; params stay replicated"),
        headers.iter().map(String::as_str).collect(),
    );
    for model in presets::paper_models() {
        let p = model.param_count();
        let mb = |w: usize, st: usize| -> String {
            let m = RankMemory::with_grad_dtype(p, w, st, dtype);
            format!("{:.1}", (m.grad_bytes + m.optimizer_bytes) / 1e6)
        };
        let mut cells = vec![model.variant.clone()];
        for st in ZERO_STAGES {
            if st == 0 {
                cells.push(mb(1, 0));
            } else {
                cells.extend(WORLDS.iter().map(|&w| mb(w, st)));
            }
        }
        t.row(&cells);
    }
    println!("{}", t.render());
    println!("  stage 1 shards the 8 bytes/param of fp32 moments \
              across the DP world;\n  stage 2 also shards the gradient \
              buffer (free-on-reduce), so at 256 GPUs\n  the 350M \
              model's ~2.7 GB of per-rank state shrinks to ~15 MB.\n");

    section("simulated: full-step effect at 128 nodes");
    let headers = vec!["model".to_string(), "batch".into(),
                       "step0(ms)".into(),
                       format!("step{stage}(ms)"),
                       "exposed0(ms)".into(),
                       format!("exposed{stage}(ms)"),
                       format!("grad-mem{stage}(MB)"),
                       format!("opt-mem{stage}(MB)"),
                       format!("headroom{stage}(GB)")];
    let mut t = Table::new(
        &format!("zero_stage 0 vs {stage} (paper cluster, overlap on)"),
        headers.iter().map(String::as_str).collect(),
    );
    for model in presets::paper_models() {
        let mut cfg = presets::paper_full_scale();
        cfg.training.batch_per_gpu =
            presets::artifact_batch(&model.variant);
        cfg.model = model.clone();
        cfg.training.zero_stage = 0;
        let s0 = simulate(&cfg);
        cfg.training.zero_stage = stage;
        let s1 = simulate(&cfg);
        t.row(&[
            model.variant.clone(),
            s1.batch_per_gpu.to_string(),
            format!("{:.1}", s0.step_secs * 1e3),
            format!("{:.1}", s1.step_secs * 1e3),
            format!("{:.1}", s0.comm_exposed_secs * 1e3),
            format!("{:.1}", s1.comm_exposed_secs * 1e3),
            format!("{:.1}", s1.grad_bytes_per_rank / 1e6),
            format!("{:.1}", s1.opt_bytes_per_rank / 1e6),
            format!("{:.2}", s1.mem_headroom_bytes / 1e9),
        ]);
    }
    println!("{}", t.render());
    println!("  the exposed delta is the post-step parameter \
              all-gather — the price of\n  freeing the sharded bytes \
              per rank. It pays off when the freed memory buys\n  a \
              bigger micro-batch (set batch_per_gpu=0 to let the sim \
              solve it).\n");

    section("analytic: RS+AG vs all-reduce wire time (ring, 128 nodes)");
    let cost = CostModel::from_cluster(
        &presets::paper_full_scale().cluster);
    for params in [109_076_400u64, 334_616_496] {
        let bytes = CostModel::gradient_bytes(params);
        let ar = cost.ring_allreduce(128, bytes);
        let rs = cost.ring_reduce_scatter(128, bytes);
        let ag = cost.ring_all_gather(128, bytes);
        println!(
            "  {:>5.0}M params: allreduce {:>6.1} ms = RS {:>6.1} + AG \
             {:>6.1} ms",
            params as f64 / 1e6, ar * 1e3, rs * 1e3, ag * 1e3
        );
    }
    println!();

    section("real: sharded schedules vs monolithic, per transport");
    let world = 4usize;
    let len = 8_500_000usize; // e2e-scale gradient
    let plan = BucketPlan::from_elems(len, len / 6 + 1);
    let run_allreduce = |backend: Backend| -> f64 {
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            let handles: Vec<_> = backend
                .world(world)
                .unwrap()
                .into_iter()
                .map(|mut c| {
                    s.spawn(move || {
                        let mut buf = vec![1.0f32; len];
                        allreduce(Algorithm::Ring, &mut c, &mut buf)
                            .unwrap();
                        black_box(buf[0]);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        t0.elapsed().as_secs_f64()
    };
    let mut t = Table::new(
        &format!("world=4, 8.5M floats, grad_dtype {dtype} (mean of 3) \
                  — same wire bytes per row"),
        vec!["transport", "stage-1(ms)", "stage-2(ms)",
             "all-reduce(ms)", "s1 peak(MB)", "s2 peak(MB)"],
    );
    for backend in Backend::ALL {
        let mut t1 = 0.0;
        let mut t2 = 0.0;
        let mut ar = 0.0;
        let mut p1 = 0u64;
        let mut p2 = 0u64;
        for _ in 0..3 {
            let (secs, peak) = run_stage1(backend, world, len, &plan);
            t1 += secs;
            p1 = p1.max(peak);
            let (secs, peak) =
                run_stage2(backend, world, len, &plan, dtype);
            t2 += secs;
            p2 = p2.max(peak);
            ar += run_allreduce(backend);
        }
        t.row(&[backend.to_string(), format!("{:.2}", t1 / 3.0 * 1e3),
                format!("{:.2}", t2 / 3.0 * 1e3),
                format!("{:.2}", ar / 3.0 * 1e3),
                format!("{:.1}", p1 as f64 / 1e6),
                format!("{:.1}", p2 as f64 / 1e6)]);
    }
    println!("{}", t.render());
    println!("  (same bytes on the wire; stage 2 swaps the resident \
              4-byte gradient buffer\n  for per-bucket staging copies \
              plus a {dtype} shard store — the measured peak\n  \
              column, which verify.sh gates with `--smoke`. The \
              channel/shm vs tcp\n  spread is pure transport cost: \
              pointer moves vs genuine loopback\n  serialization.)");

    section("hot path");
    bench("bucketed reduce-scatter, world=4, 8.5M floats", 2000, || {
        std::thread::scope(|s| {
            let handles: Vec<_> = Backend::Channel
                .world(world)
                .unwrap()
                .into_iter()
                .map(|mut c| {
                    let plan = plan.clone();
                    s.spawn(move || {
                        let mut buf = vec![1.0f32; len];
                        bucketed_reduce_scatter(Algorithm::Ring, &mut c,
                                                &mut buf, &plan)
                            .unwrap();
                        black_box(buf[0]);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    });
}
