//! BENCH REC6-ZERO: the ZeRO-1 sharded-optimizer ablation behind the
//! `training.zero_stage` knob.
//!
//! Part 1 sweeps world size through the analytic memory model and
//! shows the 1/N optimizer-state curve — the memory that becomes
//! micro-batch headroom (the paper's rec. 5 lever). Part 2 prices the
//! full step: reduce-scatter overlapped with backward plus the exposed
//! parameter all-gather, against the plain overlapped all-reduce.
//! Part 3 times the real RS → shard-write → AG pipeline against the
//! monolithic all-reduce on every transport backend: same wire bytes,
//! so the sharding must cost ~nothing extra on any wire.
//!
//! Run: `cargo bench --bench rec6_zero`

use txgain::collectives::{allreduce, bucketed_all_gather,
                          bucketed_reduce_scatter, Algorithm, Backend,
                          BucketPlan, CostModel, RankMemory};
use txgain::config::presets;
use txgain::perfmodel::simulate;
use txgain::report::Table;
use txgain::util::bench::{bench, black_box, section};

fn main() {
    section("analytic: per-rank optimizer state vs world size (1/N)");
    let mut t = Table::new(
        "Adam m+v bytes per rank (MB); params+grads stay replicated",
        vec!["model", "stage-0", "W=2", "W=8", "W=32", "W=256"],
    );
    for model in presets::paper_models() {
        let p = model.param_count();
        let mb =
            |w: usize, st: usize| -> String {
                format!("{:.1}",
                        RankMemory::new(p, w, st).optimizer_bytes / 1e6)
            };
        t.row(&[
            model.variant.clone(),
            mb(1, 0),
            mb(2, 1),
            mb(8, 1),
            mb(32, 1),
            mb(256, 1),
        ]);
    }
    println!("{}", t.render());
    println!("  stage 1 shards the 8 bytes/param of fp32 moments \
              across the DP world;\n  at 256 GPUs the 350M model's \
              ~2.7 GB of moments shrink to ~10 MB/rank.\n");

    section("simulated: full-step effect at 128 nodes");
    let mut t = Table::new(
        "zero_stage 0 vs 1 (paper cluster, overlap on)",
        vec!["model", "batch", "step0(ms)", "step1(ms)",
             "exposed0(ms)", "exposed1(ms)", "opt-mem1(MB)",
             "headroom1(GB)"],
    );
    for model in presets::paper_models() {
        let mut cfg = presets::paper_full_scale();
        cfg.training.batch_per_gpu =
            presets::artifact_batch(&model.variant);
        cfg.model = model.clone();
        cfg.training.zero_stage = 0;
        let s0 = simulate(&cfg);
        cfg.training.zero_stage = 1;
        let s1 = simulate(&cfg);
        t.row(&[
            model.variant.clone(),
            s1.batch_per_gpu.to_string(),
            format!("{:.1}", s0.step_secs * 1e3),
            format!("{:.1}", s1.step_secs * 1e3),
            format!("{:.1}", s0.comm_exposed_secs * 1e3),
            format!("{:.1}", s1.comm_exposed_secs * 1e3),
            format!("{:.1}", s1.opt_bytes_per_rank / 1e6),
            format!("{:.2}", s1.mem_headroom_bytes / 1e9),
        ]);
    }
    println!("{}", t.render());
    println!("  the exposed delta is the post-step parameter \
              all-gather — the price of\n  freeing 8·P·(1−1/W) \
              bytes/rank. It pays off when the freed memory buys\n  a \
              bigger micro-batch (set batch_per_gpu=0 to let the sim \
              solve it).\n");

    section("analytic: RS+AG vs all-reduce wire time (ring, 128 nodes)");
    let cost = CostModel::from_cluster(
        &presets::paper_full_scale().cluster);
    for params in [109_076_400u64, 334_616_496] {
        let bytes = CostModel::gradient_bytes(params);
        let ar = cost.ring_allreduce(128, bytes);
        let rs = cost.ring_reduce_scatter(128, bytes);
        let ag = cost.ring_all_gather(128, bytes);
        println!(
            "  {:>5.0}M params: allreduce {:>6.1} ms = RS {:>6.1} + AG \
             {:>6.1} ms",
            params as f64 / 1e6, ar * 1e3, rs * 1e3, ag * 1e3
        );
    }
    println!();

    section("real: RS + shard write + AG vs monolithic, per transport");
    let world = 4usize;
    let len = 8_500_000usize; // e2e-scale gradient
    let plan = BucketPlan::from_elems(len, len / 6 + 1);
    let run_zero = |backend: Backend, plan: &BucketPlan| -> f64 {
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            let handles: Vec<_> = backend
                .world(world)
                .unwrap()
                .into_iter()
                .enumerate()
                .map(|(rank, mut c)| {
                    let plan = plan.clone();
                    s.spawn(move || {
                        let mut buf = vec![1.0f32; len];
                        bucketed_reduce_scatter(Algorithm::Ring, &mut c,
                                                &mut buf, &plan)
                            .unwrap();
                        for &(a, b) in &plan.rank_ranges(rank, world) {
                            for x in &mut buf[a..b] {
                                *x *= 0.5; // the "optimizer step"
                            }
                        }
                        bucketed_all_gather(Algorithm::Ring, &mut c,
                                            &mut buf, &plan)
                            .unwrap();
                        black_box(buf[0]);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        t0.elapsed().as_secs_f64()
    };
    let run_allreduce = |backend: Backend| -> f64 {
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            let handles: Vec<_> = backend
                .world(world)
                .unwrap()
                .into_iter()
                .map(|mut c| {
                    s.spawn(move || {
                        let mut buf = vec![1.0f32; len];
                        allreduce(Algorithm::Ring, &mut c, &mut buf)
                            .unwrap();
                        black_box(buf[0]);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        t0.elapsed().as_secs_f64()
    };
    let mut t = Table::new(
        "world=4, 8.5M floats (mean of 5) — same wire bytes per row",
        vec!["transport", "RS+step+AG(ms)", "all-reduce(ms)"],
    );
    for backend in Backend::ALL {
        let zero: f64 =
            (0..5).map(|_| run_zero(backend, &plan)).sum::<f64>() / 5.0;
        let ar: f64 =
            (0..5).map(|_| run_allreduce(backend)).sum::<f64>() / 5.0;
        t.row(&[backend.to_string(), format!("{:.2}", zero * 1e3),
                format!("{:.2}", ar * 1e3)]);
    }
    println!("{}", t.render());
    println!("  (same bytes on the wire; the shard write replaces \
              3/4 of the full optimizer\n  math each rank would do \
              replicated — the win ZeRO banks. The channel/shm\n  vs \
              tcp spread is pure transport cost: pointer moves vs \
              genuine loopback\n  serialization.)");

    section("hot path");
    bench("bucketed reduce-scatter, world=4, 8.5M floats", 2000, || {
        std::thread::scope(|s| {
            let handles: Vec<_> = Backend::Channel
                .world(world)
                .unwrap()
                .into_iter()
                .map(|mut c| {
                    let plan = plan.clone();
                    s.spawn(move || {
                        let mut buf = vec![1.0f32; len];
                        bucketed_reduce_scatter(Algorithm::Ring, &mut c,
                                                &mut buf, &plan)
                            .unwrap();
                        black_box(buf[0]);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    });
}
