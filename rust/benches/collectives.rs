//! BENCH collectives: real ring vs tree all-reduce across world sizes,
//! buffer sizes and transport backends, plus the α-β cost model's
//! projected times on TX-GAIN for the same shapes — the ablation behind
//! the `training.allreduce` and `training.transport` config knobs.
//!
//! Run: `cargo bench --bench collectives`

use txgain::collectives::{allreduce, Algorithm, Backend, CostModel};
use txgain::config::ClusterConfig;
use txgain::report::Table;
use txgain::util::bench::{bench, black_box, section};

fn run_real(backend: Backend, algo: Algorithm, world: usize,
            len: usize) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        let handles: Vec<_> = backend
            .world(world)
            .unwrap()
            .into_iter()
            .map(|mut c| {
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    allreduce(algo, &mut c, &mut buf).unwrap();
                    black_box(buf[0]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    t0.elapsed().as_secs_f64()
}

fn main() {
    section("real in-process all-reduce: ring vs tree (channel)");
    let mut t = Table::new(
        "wall time per all-reduce (mean of 5)",
        vec!["world", "floats", "ring(ms)", "tree(ms)", "winner"],
    );
    for world in [2usize, 4, 8] {
        for len in [1_000usize, 100_000, 8_500_000] {
            let avg = |algo| -> f64 {
                (0..5)
                    .map(|_| run_real(Backend::Channel, algo, world,
                                      len))
                    .sum::<f64>()
                    / 5.0
            };
            let ring = avg(Algorithm::Ring);
            let tree = avg(Algorithm::Tree);
            t.row(&[
                world.to_string(),
                len.to_string(),
                format!("{:.2}", ring * 1e3),
                format!("{:.2}", tree * 1e3),
                (if ring < tree { "ring" } else { "tree" }).to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    section("real ring all-reduce per transport backend");
    let mut t = Table::new(
        "wall time per ring all-reduce, world=4 (mean of 5)",
        vec!["floats", "channel(ms)", "shm(ms)", "tcp(ms)"],
    );
    for len in [1_000usize, 100_000, 8_500_000] {
        let mut cells = vec![len.to_string()];
        for backend in Backend::ALL {
            let avg = (0..5)
                .map(|_| run_real(backend, Algorithm::Ring, 4, len))
                .sum::<f64>()
                / 5.0;
            cells.push(format!("{:.2}", avg * 1e3));
        }
        t.row(&cells);
    }
    println!("{}", t.render());
    println!("  channel/shm hand buffers over in-process; tcp pays \
              real serialization\n  and syscalls per hop — the gap is \
              the transport tier, not the algorithm.");

    section("α-β model projection on TX-GAIN (25 GbE + NVLink)");
    let cost = CostModel::from_cluster(&ClusterConfig::tx_gain(128));
    let mut t = Table::new(
        "projected all-reduce time, bf16 gradients",
        vec!["nodes", "model", "bytes", "ring(ms)", "tree(ms)"],
    );
    for nodes in [8usize, 32, 128] {
        for (name, params) in
            [("bert-120m", 109_076_400u64), ("bert-350m", 334_616_496)]
        {
            let bytes = CostModel::gradient_bytes(params);
            t.row(&[
                nodes.to_string(),
                name.to_string(),
                format!("{:.0}M", bytes / 1e6),
                format!("{:.1}", cost.ring_allreduce(nodes, bytes) * 1e3),
                format!("{:.1}", cost.tree_allreduce(nodes, bytes) * 1e3),
            ]);
        }
    }
    println!("{}", t.render());

    section("hot path");
    bench("ring all-reduce, world=4, 8.5M floats (e2e grads)", 2000,
          || {
              black_box(run_real(Backend::Channel, Algorithm::Ring, 4,
                                 8_500_000));
          });
}
