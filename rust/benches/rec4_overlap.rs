//! BENCH REC4-OVERLAP: the gradient-bucketing ablation behind the
//! `training.overlap_comm` / `training.bucket_mb` knobs.
//!
//! Part 1 sweeps bucket size through the simulator's overlap pricing
//! and reports the exposed all-reduce time against the blocking
//! baseline (the paper's Fig. 1 step-anatomy argument: exposed comm is
//! what kills scaling efficiency at high node counts). Part 2 times the
//! real bucketed all-reduce against the monolithic one — on every
//! transport backend, so the bucketing overhead is visible per wire.
//!
//! Run: `cargo bench --bench rec4_overlap`
//!
//! The hot-path bench runs on the preset's `training.transport` knob;
//! override it with `TXGAIN_TRANSPORT=channel|shm|tcp`.

use txgain::collectives::{allreduce, bucketed_allreduce, Algorithm,
                          AnyTransport, Backend, BucketPlan, CostModel};
use txgain::config::{presets, ClusterConfig};
use txgain::perfmodel::simulate;
use txgain::report::Table;
use txgain::util::bench::{bench, black_box, section};

/// Backend under benchmark: the `TXGAIN_TRANSPORT` env var if set,
/// else the quickstart preset's `training.transport` knob.
fn configured_backend() -> Backend {
    std::env::var("TXGAIN_TRANSPORT")
        .unwrap_or_else(|_| presets::quickstart().training.transport)
        .parse()
        .expect("TXGAIN_TRANSPORT / training.transport")
}

fn main() {
    section("simulated: exposed comm vs bucket size (ring, bf16 grads)");
    let cost = CostModel::from_cluster(&ClusterConfig::tx_gain(128));
    let mut t = Table::new(
        "exposed all-reduce (ms); blocking = no overlap",
        vec!["model", "nodes", "blocking", "1MB", "5MB", "25MB", "100MB",
             "one-bucket"],
    );
    for (name, params, backward_ms) in [
        ("bert-120m", 109_076_400u64, 250.0f64),
        ("bert-350m", 334_616_496, 369.0),
    ] {
        let bytes = CostModel::gradient_bytes(params);
        let bwd = backward_ms * 1e-3;
        for nodes in [8usize, 32, 128] {
            let blocking = cost.ring_allreduce(nodes, bytes);
            // bucket_mb counts f32 buffer bytes; the wire carries half
            // (bf16) — same mapping simtrain uses for the config knob
            let exposed = |mb: f64| -> f64 {
                cost.overlapped_allreduce(Algorithm::Ring, nodes, bytes,
                                          mb * 1e6 / 2.0, bwd)
                    .exposed
            };
            t.row(&[
                name.to_string(),
                nodes.to_string(),
                format!("{:.1}", blocking * 1e3),
                format!("{:.1}", exposed(1.0) * 1e3),
                format!("{:.1}", exposed(5.0) * 1e3),
                format!("{:.1}", exposed(25.0) * 1e3),
                format!("{:.1}", exposed(100.0) * 1e3),
                format!("{:.1}", exposed(4.0 * bytes / 1e6) * 1e3),
            ]);
        }
    }
    println!("{}", t.render());
    println!("  25 MB (the DDP default) starts the pipeline early \
              without drowning in per-message latency.\n");

    section("simulated: full-step effect at 128 nodes (bert-120m)");
    let mut cfg = presets::paper_full_scale();
    cfg.training.overlap_comm = false;
    let off = simulate(&cfg);
    cfg.training.overlap_comm = true;
    let on = simulate(&cfg);
    println!(
        "  blocking : step {:>7.1} ms, comm exposed {:>6.1} ms, \
         gpu-util {:.3}",
        off.step_secs * 1e3, off.comm_exposed_secs * 1e3, off.gpu_util
    );
    println!(
        "  overlap  : step {:>7.1} ms, comm exposed {:>6.1} ms, \
         gpu-util {:.3}  ({} buckets)",
        on.step_secs * 1e3, on.comm_exposed_secs * 1e3, on.gpu_util,
        on.comm_buckets
    );

    section("real: bucketed vs monolithic all-reduce, per transport");
    let world = 4usize;
    let len = 8_500_000usize; // e2e-scale gradient
    let run = |backend: Backend, bucket_elems: Option<usize>| -> f64 {
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            let handles: Vec<_> = backend
                .world(world)
                .unwrap()
                .into_iter()
                .map(|mut c: AnyTransport| {
                    s.spawn(move || {
                        let mut buf = vec![1.0f32; len];
                        match bucket_elems {
                            Some(e) => {
                                let plan =
                                    BucketPlan::from_elems(len, e);
                                bucketed_allreduce(Algorithm::Ring,
                                                   &mut c, &mut buf,
                                                   &plan)
                                    .unwrap();
                            }
                            None => allreduce(Algorithm::Ring, &mut c,
                                              &mut buf)
                                .unwrap(),
                        }
                        black_box(buf[0]);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        t0.elapsed().as_secs_f64()
    };
    let mut t = Table::new(
        "wall time per all-reduce, world=4, 8.5M floats (mean of 5)",
        vec!["buckets", "channel(ms)", "shm(ms)", "tcp(ms)"],
    );
    for (label, elems) in [
        ("monolithic", None),
        ("2 x ~17MB", Some(len / 2 + 1)),
        ("6 x ~6MB", Some(len / 6 + 1)),
        ("14 x ~2.5MB", Some(len / 14 + 1)),
    ] {
        let mut cells = vec![label.to_string()];
        for backend in Backend::ALL {
            let avg = (0..5).map(|_| run(backend, elems)).sum::<f64>()
                / 5.0;
            cells.push(format!("{:.2}", avg * 1e3));
        }
        t.row(&cells);
    }
    println!("{}", t.render());
    println!("  (channel/shm move pointers, tcp genuinely serializes \
              every byte through\n  loopback sockets — the per-wire \
              spread is the transport tier the simulator's\n  α-β \
              model prices; bucketing must stay cheap on all three)");

    section("hot path");
    let backend = configured_backend();
    bench(&format!("bucketed ring all-reduce, world=4, 8.5M floats, \
                    25MB, {backend}"),
          2000, || {
              black_box(run(backend, Some(6_250_000)));
          });
}
