//! BENCH REC4-OVERLAP: the gradient-bucketing ablation behind the
//! `training.overlap_comm` / `training.bucket_mb` /
//! `training.comm_engine` knobs.
//!
//! Part 1 sweeps bucket size through the simulator's overlap pricing
//! and reports the exposed all-reduce time against the blocking
//! baseline (the paper's Fig. 1 step-anatomy argument: exposed comm is
//! what kills scaling efficiency at high node counts). Part 2 times the
//! real bucketed all-reduce against the monolithic one — on every
//! transport backend, so the bucketing overhead is visible per wire.
//! Part 3 is the tentpole measurement: *wall-clock* exposed comm with
//! the async comm engine vs the blocking transports, under an emulated
//! layer-by-layer backward — the measured counterpart of part 1's
//! model.
//!
//! Run: `cargo bench --bench rec4_overlap`
//! Smoke gate (used by verify.sh): `cargo bench --bench rec4_overlap
//! -- --smoke` asserts (a) engine-exposed ≤ blocking-exposed at world
//! 4 on shm, (b) hierarchical exposed ≤ flat ring on the two-tier
//! hier transport at an emulated 2 nodes × 4 ranks, and (c) the bf16
//! wire exposed ≤ the f32 wire on tcp at world 4 (half the bytes must
//! not cost more wall-clock); exits nonzero on regression.
//!
//! The hot-path bench runs on the preset's `training.transport` knob;
//! override it with `TXGAIN_TRANSPORT=channel|shm|tcp|hier`.

use std::time::Instant;

use txgain::collectives::{allreduce, bucketed_allreduce, Algorithm,
                          AnyTransport, Backend, BucketPlan,
                          CollectiveKind, CommEngine, CostModel,
                          PendingBucket, Topology, WireCodec};
use txgain::config::{presets, ClusterConfig};
use txgain::perfmodel::simulate;
use txgain::report::Table;
use txgain::util::bench::{bench, black_box, section};

/// Backend under benchmark: the `TXGAIN_TRANSPORT` env var if set,
/// else the quickstart preset's `training.transport` knob.
fn configured_backend() -> Backend {
    std::env::var("TXGAIN_TRANSPORT")
        .unwrap_or_else(|_| presets::quickstart().training.transport)
        .parse()
        .expect("TXGAIN_TRANSPORT / training.transport")
}

/// One emulated training step on every rank: `n_buckets` backward
/// "layer slices" of `slice_secs` each (sleeps, so a progress thread
/// can genuinely use the core), the bucket launched after its slice
/// retires — blocking inline, or through the comm engine with the
/// waits at the end. Returns the slowest rank's
/// `(step_secs, exposed_comm_secs)`; exposed is time the trainer
/// thread actually spent blocked on comm, i.e. the measured
/// `comm_exposed_ms`.
#[allow(clippy::too_many_arguments)]
fn measured_step(backend: Backend, topo: Option<&Topology>,
                 codec: WireCodec, world: usize, len: usize,
                 n_buckets: usize, slice_secs: f64, algo: Algorithm,
                 engine: bool)
    -> (f64, f64) {
    let plan = BucketPlan::from_elems(len, len / n_buckets + 1);
    let per_rank: Vec<(f64, f64)> = std::thread::scope(|s| {
        backend
            .world_with(world, topo, codec)
            .unwrap()
            .into_iter()
            .map(|c| {
                let plan = plan.clone();
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    let t0 = Instant::now();
                    let mut exposed = 0.0f64;
                    if engine {
                        let mut eng = CommEngine::new(c);
                        let mut pend: Vec<(usize, PendingBucket)> =
                            Vec::new();
                        for i in plan.ready_order() {
                            std::thread::sleep(
                                std::time::Duration::from_secs_f64(
                                    slice_secs));
                            let (a, b) = plan.span(i);
                            let t = Instant::now();
                            let p = eng
                                .launch_bucket(
                                    algo,
                                    CollectiveKind::Allreduce,
                                    buf[a..b].to_vec())
                                .unwrap();
                            exposed += t.elapsed().as_secs_f64();
                            pend.push((i, p));
                        }
                        for (i, p) in pend {
                            let (a, b) = plan.span(i);
                            let t = Instant::now();
                            let got = eng.wait(p).unwrap();
                            exposed += t.elapsed().as_secs_f64();
                            buf[a..b].copy_from_slice(&got);
                            eng.recycle(got);
                        }
                    } else {
                        let mut c = c;
                        for i in plan.ready_order() {
                            std::thread::sleep(
                                std::time::Duration::from_secs_f64(
                                    slice_secs));
                            let (a, b) = plan.span(i);
                            let t = Instant::now();
                            allreduce(algo, &mut c, &mut buf[a..b])
                                .unwrap();
                            exposed += t.elapsed().as_secs_f64();
                        }
                    }
                    black_box(buf[0]);
                    (t0.elapsed().as_secs_f64(), exposed)
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    per_rank.iter().fold((0.0f64, 0.0f64), |acc, r| {
        (acc.0.max(r.0), acc.1.max(r.1))
    })
}

/// The verify.sh smoke gate: measured exposed comm with the engine
/// must not exceed the blocking baseline at world 4 on shm. Means of
/// `trials` steps; panics (nonzero exit) on regression. A small
/// scheduler-noise tolerance keeps the gate meaningful without making
/// tier-1 a timing flake on loaded machines; a genuinely serialized
/// engine exposes the *whole* sync and blows far past it.
fn smoke() {
    let world = 4usize;
    let len = 2_000_000usize;
    let buckets = 8usize;
    let slice = 2e-3;
    let trials = 5usize;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores < 2 {
        // a single hardware thread cannot run a progress thread
        // concurrently with compute at all — the measurement would
        // only gauge the scheduler, not the engine
        println!("rec4 smoke: SKIP (1 hardware thread — no \
                  concurrency to measure)");
        return;
    }
    let mean = |engine: bool| -> (f64, f64) {
        let mut step = 0.0;
        let mut exposed = 0.0;
        for _ in 0..trials {
            let (s, e) = measured_step(Backend::Shm, None,
                                       WireCodec::F32, world, len,
                                       buckets, slice, Algorithm::Ring,
                                       engine);
            step += s;
            exposed += e;
        }
        (step / trials as f64, exposed / trials as f64)
    };
    let (bs, be) = mean(false);
    let (es, ee) = mean(true);
    println!(
        "rec4 smoke [shm, world {world}, {len} floats, {buckets} \
         buckets, {cores} cores]:\n  blocking: step {:7.2} ms, \
         exposed {:7.2} ms\n  engine  : step {:7.2} ms, exposed \
         {:7.2} ms",
        bs * 1e3, be * 1e3, es * 1e3, ee * 1e3
    );
    let tolerance = be * 0.10 + 1e-3;
    assert!(
        ee <= be + tolerance,
        "SMOKE FAIL: engine exposed {:.2} ms > blocking {:.2} ms \
         (+10% noise margin) — the comm engine is not hiding \
         communication",
        ee * 1e3, be * 1e3
    );
    println!("rec4 smoke: OK (engine exposes {:.0}% of the blocking \
              baseline)",
             ee / be.max(1e-12) * 100.0);
    smoke_hier();
    smoke_bf16();
}

/// The hierarchical half of the smoke gate: on an emulated
/// 2 nodes × 4 ranks (shm within a group, tcp loopback between the
/// leaders), a blocking hierarchical all-reduce must not expose more
/// than the flat ring on the *same* two-tier transport — the flat ring
/// drags 2(W−1) of its hops across the slow tier, the hierarchical
/// schedule crosses it 2(N−1) times. Same noise margin as above.
fn smoke_hier() {
    let world = 8usize;
    let topo: Topology = "4,4".parse().unwrap();
    let len = 2_000_000usize;
    let buckets = 4usize;
    let trials = 3usize;
    let mean = |algo: Algorithm| -> f64 {
        let mut exposed = 0.0;
        for _ in 0..trials {
            exposed += measured_step(Backend::Hier, Some(&topo),
                                     WireCodec::F32, world, len,
                                     buckets, 0.0, algo, false)
                .1;
        }
        exposed / trials as f64
    };
    let flat = mean(Algorithm::Ring);
    let hier = mean(Algorithm::Hierarchical);
    println!(
        "rec4 smoke [hier, 2 nodes x 4 ranks, {len} floats, {buckets} \
         buckets]:\n  flat ring    : exposed {:7.2} ms\n  \
         hierarchical : exposed {:7.2} ms",
        flat * 1e3, hier * 1e3
    );
    let tolerance = flat * 0.10 + 1e-3;
    assert!(
        hier <= flat + tolerance,
        "SMOKE FAIL: hierarchical exposed {:.2} ms > flat ring {:.2} \
         ms (+10% noise margin) on the two-tier transport — the \
         topology-aware schedule is not paying off",
        hier * 1e3, flat * 1e3
    );
    println!("rec4 smoke: OK (hierarchical exposes {:.0}% of the flat \
              ring)",
             hier / flat.max(1e-12) * 100.0);
}

/// The wire-codec half of the smoke gate: on tcp — the one backend
/// that genuinely serializes every byte through a socket — a blocking
/// ring all-reduce on the bf16 wire must not expose more than the same
/// collective on the f32 wire. bf16 moves exactly half the payload
/// bytes, so if the reduced-precision path ever costs more wall-clock
/// than full precision, the codec is doing its conversions on the
/// critical path instead of at the transport boundary. Same noise
/// margin as the other gates.
fn smoke_bf16() {
    let world = 4usize;
    let len = 2_000_000usize;
    let buckets = 4usize;
    let trials = 3usize;
    let mean = |codec: WireCodec| -> f64 {
        let mut exposed = 0.0;
        for _ in 0..trials {
            exposed += measured_step(Backend::Tcp, None, codec, world,
                                     len, buckets, 0.0,
                                     Algorithm::Ring, false)
                .1;
        }
        exposed / trials as f64
    };
    let f32_wire = mean(WireCodec::F32);
    let bf16_wire = mean(WireCodec::Bf16);
    println!(
        "rec4 smoke [tcp, world {world}, {len} floats, {buckets} \
         buckets]:\n  f32 wire  : exposed {:7.2} ms\n  bf16 wire : \
         exposed {:7.2} ms",
        f32_wire * 1e3, bf16_wire * 1e3
    );
    let tolerance = f32_wire * 0.10 + 1e-3;
    assert!(
        bf16_wire <= f32_wire + tolerance,
        "SMOKE FAIL: bf16 wire exposed {:.2} ms > f32 wire {:.2} ms \
         (+10% noise margin) on tcp — the half-width wire is not \
         paying for its conversions",
        bf16_wire * 1e3, f32_wire * 1e3
    );
    println!("rec4 smoke: OK (bf16 wire exposes {:.0}% of the f32 \
              wire)",
             bf16_wire / f32_wire.max(1e-12) * 100.0);
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }
    section("simulated: exposed comm vs bucket size (ring, bf16 grads)");
    let cost = CostModel::from_cluster(&ClusterConfig::tx_gain(128));
    let mut t = Table::new(
        "exposed all-reduce (ms); blocking = no overlap",
        vec!["model", "nodes", "blocking", "1MB", "5MB", "25MB", "100MB",
             "one-bucket"],
    );
    for (name, params, backward_ms) in [
        ("bert-120m", 109_076_400u64, 250.0f64),
        ("bert-350m", 334_616_496, 369.0),
    ] {
        let bytes = CostModel::gradient_bytes(params);
        let bwd = backward_ms * 1e-3;
        for nodes in [8usize, 32, 128] {
            let blocking = cost.ring_allreduce(nodes, bytes);
            // bucket_mb counts f32 buffer bytes; the wire carries half
            // (bf16) — same mapping simtrain uses for the config knob
            let exposed = |mb: f64| -> f64 {
                cost.overlapped_allreduce(Algorithm::Ring, nodes, bytes,
                                          mb * 1e6 / 2.0, bwd)
                    .exposed
            };
            t.row(&[
                name.to_string(),
                nodes.to_string(),
                format!("{:.1}", blocking * 1e3),
                format!("{:.1}", exposed(1.0) * 1e3),
                format!("{:.1}", exposed(5.0) * 1e3),
                format!("{:.1}", exposed(25.0) * 1e3),
                format!("{:.1}", exposed(100.0) * 1e3),
                format!("{:.1}", exposed(4.0 * bytes / 1e6) * 1e3),
            ]);
        }
    }
    println!("{}", t.render());
    println!("  25 MB (the DDP default) starts the pipeline early \
              without drowning in per-message latency.\n");

    section("simulated: full-step effect at 128 nodes (bert-120m)");
    let mut cfg = presets::paper_full_scale();
    cfg.training.overlap_comm = false;
    let off = simulate(&cfg);
    cfg.training.overlap_comm = true;
    let on = simulate(&cfg);
    println!(
        "  blocking : step {:>7.1} ms, comm exposed {:>6.1} ms, \
         gpu-util {:.3}",
        off.step_secs * 1e3, off.comm_exposed_secs * 1e3, off.gpu_util
    );
    println!(
        "  overlap  : step {:>7.1} ms, comm exposed {:>6.1} ms, \
         gpu-util {:.3}  ({} buckets)",
        on.step_secs * 1e3, on.comm_exposed_secs * 1e3, on.gpu_util,
        on.comm_buckets
    );

    section("real: bucketed vs monolithic all-reduce, per transport");
    let world = 4usize;
    let len = 8_500_000usize; // e2e-scale gradient
    let run = |backend: Backend, bucket_elems: Option<usize>| -> f64 {
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            let handles: Vec<_> = backend
                .world(world)
                .unwrap()
                .into_iter()
                .map(|mut c: AnyTransport| {
                    s.spawn(move || {
                        let mut buf = vec![1.0f32; len];
                        match bucket_elems {
                            Some(e) => {
                                let plan =
                                    BucketPlan::from_elems(len, e);
                                bucketed_allreduce(Algorithm::Ring,
                                                   &mut c, &mut buf,
                                                   &plan)
                                    .unwrap();
                            }
                            None => allreduce(Algorithm::Ring, &mut c,
                                              &mut buf)
                                .unwrap(),
                        }
                        black_box(buf[0]);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        t0.elapsed().as_secs_f64()
    };
    let mut headers = vec!["buckets".to_string()];
    headers.extend(Backend::ALL.iter().map(|b| format!("{b}(ms)")));
    let mut t = Table::new(
        "wall time per all-reduce, world=4, 8.5M floats (mean of 5)",
        headers.iter().map(String::as_str).collect(),
    );
    for (label, elems) in [
        ("monolithic", None),
        ("2 x ~17MB", Some(len / 2 + 1)),
        ("6 x ~6MB", Some(len / 6 + 1)),
        ("14 x ~2.5MB", Some(len / 14 + 1)),
    ] {
        let mut cells = vec![label.to_string()];
        for backend in Backend::ALL {
            let avg = (0..5).map(|_| run(backend, elems)).sum::<f64>()
                / 5.0;
            cells.push(format!("{:.2}", avg * 1e3));
        }
        t.row(&cells);
    }
    println!("{}", t.render());
    println!("  (channel/shm move pointers, tcp genuinely serializes \
              every byte through\n  loopback sockets — the per-wire \
              spread is the transport tier the simulator's\n  α-β \
              model prices; bucketing must stay cheap on all three)");

    section("real: measured wall-clock exposed comm — engine vs \
             blocking");
    // the tentpole measurement: an emulated layer-by-layer backward
    // (8 × 2 ms sleep slices) retires buckets one at a time; blocking
    // transports sync each bucket inline (everything exposed), the
    // comm engine pipelines them under the remaining slices and only
    // the launch/wait time is exposed — the same quantity the trainer
    // records as comm_exposed_ms
    let world = 4usize;
    let len = 2_000_000usize;
    let buckets = 8usize;
    let slice = 2e-3;
    let mut headers = vec!["driver".to_string()];
    headers.extend(Backend::ALL.iter().map(|b| b.to_string()));
    let mut t = Table::new(
        "exposed comm (ms), world=4, 2M floats, 8 buckets, 2ms/layer \
         (mean of 3)",
        headers.iter().map(String::as_str).collect(),
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for engine in [false, true] {
        let mut cells =
            vec![if engine { "engine" } else { "blocking" }.to_string()];
        for backend in Backend::ALL {
            let mut exposed = 0.0;
            for _ in 0..3 {
                exposed += measured_step(backend, None, WireCodec::F32,
                                         world, len, buckets, slice,
                                         Algorithm::Ring, engine)
                    .1;
            }
            cells.push(format!("{:.2}", exposed / 3.0 * 1e3));
        }
        rows.push(cells);
    }
    for r in &rows {
        t.row(r);
    }
    println!("{}", t.render());
    println!("  blocking exposes the whole sync; the engine leaves \
              only the launch/wait\n  residue — the measured \
              counterpart of the simulated table above\n  (verify.sh \
              gates on this with `--smoke`)");

    section("hot path");
    let backend = configured_backend();
    bench(&format!("bucketed ring all-reduce, world=4, 8.5M floats, \
                    25MB, {backend}"),
          2000, || {
              black_box(run(backend, Some(6_250_000)));
          });
}
