//! BENCH REC3-STREAM: the memory-bounded data plane under pressure —
//! `loaders_per_gpu` × `cache_mb` × staging policy.
//!
//! Two substrates:
//!  * modeled (paper scale): the cache-aware loader term — an
//!    undersized cache multiplies the disk stream and, under contended
//!    network-direct staging, re-creates rec. 3's utilization sawtooth
//!    with a disk axis;
//!  * the real streaming `LoaderPool` over real shard files: workers ×
//!    cache budget, measuring wall time, hit rate and bytes pulled, and
//!    pricing the measured stream with the staging cost model
//!    (`staging::price_read`) — the measured-vs-modeled cross-check.
//!
//! Run: `cargo bench --bench rec3_stream`

use std::sync::atomic::Ordering;
use std::sync::Arc;

use txgain::config::{presets, StagingPolicy};
use txgain::data::records::Sample;
use txgain::data::{staging, BlockCache, DatasetIndex, LoaderPool,
                   Masker, ShardWriter, WindowedPlan};
use txgain::perfmodel::simulate;
use txgain::report::Table;
use txgain::util::bench::{black_box, section};

fn build_shards(dir: &std::path::Path, shards: usize, per: usize,
                seq: usize) -> Vec<std::path::PathBuf> {
    let mut paths = Vec::new();
    for si in 0..shards {
        let p = dir.join(format!("shard-{si:03}.bin"));
        let mut w = ShardWriter::create(&p, seq).unwrap();
        for i in 0..per {
            let toks: Vec<u16> = (0..seq - 2)
                .map(|j| 4 + ((si * per + i * 13 + j) % 250) as u16)
                .collect();
            w.write(&Sample::from_tokens(&toks, seq)).unwrap();
        }
        w.finish().unwrap();
        paths.push(p);
    }
    paths
}

fn main() {
    section("REC 3 — modeled: cache_mb x loaders x staging (bert-120m \
             @128 nodes, 64K-sample windows)");
    let mut t = Table::new(
        "loader stream vs cache budget",
        vec!["staging", "loaders/GPU", "cache(MB)", "io/step(MB)",
             "fetch-exposed(ms)", "gpu-util"],
    );
    let mut cfg = presets::paper_full_scale();
    cfg.data.shuffle_window = 65536; // ~67 MB at seq 512: cache matters
    for policy in [StagingPolicy::LocalCopy,
                   StagingPolicy::NetworkDirect] {
        cfg.data.staging = policy;
        for loaders in [2usize, 8, 32] {
            cfg.data.loaders_per_gpu = loaders;
            for cache_mb in [1.0f64, 16.0, 64.0, 128.0] {
                cfg.data.cache_mb = cache_mb;
                let r = simulate(&cfg);
                t.row(&[
                    policy.as_str().to_string(),
                    loaders.to_string(),
                    format!("{cache_mb:.0}"),
                    format!("{:.1}", r.loader_bytes_per_step / 1e6),
                    format!("{:.1}", r.loader_exposed_secs * 1e3),
                    format!("{:.3}", r.gpu_util),
                ]);
            }
        }
    }
    println!("{}", t.render());
    println!("shape: below ~67 MB the cache stops covering the shuffle \
              window, io/step climbs toward a block per sample, and on \
              the contended array the sawtooth returns.\n");

    section("REC 3 — real streaming LoaderPool (8 shards x 2048 \
             samples, seq 128)");
    let dir = std::env::temp_dir()
        .join(format!("txgain-bench-stream-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let paths = build_shards(&dir, 8, 2048, 128);
    let index = Arc::new(DatasetIndex::open(&paths).unwrap());
    let masker = Masker::new(0.15, 8192);
    let cluster = presets::quickstart().cluster;

    let mut t = Table::new(
        "one epoch, batch 8, world 1 (16384 samples)",
        vec!["workers", "cache(MB)", "epoch wall(ms)", "hit-rate",
             "read(MB)", "priced local(ms)", "starved(ms)"],
    );
    for workers in [1usize, 4, 8] {
        for cache_mb in [0.25f64, 1.0, 8.0, 64.0] {
            let plan = Arc::new(
                WindowedPlan::build(&index.shard_counts(), 1, 0, 7,
                                    4096)
                    .unwrap());
            let cache = Arc::new(
                BlockCache::new(index.clone(), cache_mb).unwrap());
            let t0 = std::time::Instant::now();
            let mut pool = LoaderPool::spawn_streaming(
                cache, plan, 0, 8, masker.clone(), 7, workers, 4, 0, 0)
                .unwrap();
            while let Some(b) = pool.next_batch() {
                black_box(&b);
            }
            assert!(pool.take_error().is_none());
            let wall = t0.elapsed().as_secs_f64();
            let (bytes, _, _, _) = pool.stats.io.snapshot();
            let waited = pool.stats.wait_ns.load(Ordering::Relaxed)
                as f64
                * 1e-9;
            // the cross-check: price the measured stream with the same
            // storage model the staging estimate uses
            let priced = staging::price_read(
                &cluster, StagingPolicy::LocalCopy, bytes);
            t.row(&[
                workers.to_string(),
                format!("{cache_mb:.2}"),
                format!("{:.0}", wall * 1e3),
                format!("{:.3}", pool.stats.io.hit_rate()),
                format!("{:.1}", bytes as f64 / 1e6),
                format!("{:.2}", priced * 1e3),
                format!("{:.0}", waited * 1e3),
            ]);
        }
    }
    println!("{}", t.render());
    println!("shape: hit-rate jumps once the cache covers a window; \
              read(MB) collapses to ~the corpus size read once; more \
              workers shrink starvation until the disk (or the cache \
              lock) binds.");
    let _ = std::fs::remove_dir_all(&dir);
}
