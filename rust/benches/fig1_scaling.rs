//! BENCH FIG1 + REC4: regenerates the paper's Fig. 1 (throughput vs
//! node count, one series per model size) and reports the exposed
//! all-reduce share behind recommendation 4. Also times the sweep
//! itself (the sim must stay interactive).
//!
//! Run: `cargo bench --bench fig1_scaling`

use txgain::config::presets;
use txgain::perfmodel::{scaling_efficiency, sweep_nodes};
use txgain::report;
use txgain::util::bench::{bench, black_box, section};

fn main() {
    section("FIG 1 — pretraining scaling performance (per model size)");
    let nodes = [1usize, 2, 4, 8, 16, 32, 64, 128];
    let mut series = Vec::new();
    for model in presets::paper_models() {
        let mut cfg = presets::paper_full_scale();
        cfg.training.batch_per_gpu =
            presets::artifact_batch(&model.variant);
        cfg.model = model.clone();
        let sweep = sweep_nodes(&cfg, &nodes);
        println!("{}", report::fig1_table(&model.variant, &sweep)
            .render());
        let eff = scaling_efficiency(&sweep);
        println!("  scaling efficiency @128 nodes: {:.3}  \
                  (paper: \"roughly linear\")\n", eff.last().unwrap());
        series.push((model.variant.clone(), sweep));
    }

    section("REC 4 — network is not the bottleneck (exposed comm share)");
    for (name, sweep) in &series {
        let r = sweep.last().unwrap();
        println!(
            "  {:<12} raw all-reduce {:>6.1} ms | exposed {:>6.1} ms \
             ({:>4.1}% of step)",
            name,
            r.comm_secs * 1e3,
            r.comm_exposed_secs * 1e3,
            r.comm_exposed_secs / r.step_secs * 100.0
        );
    }

    let csv_series: Vec<(&str, Vec<txgain::perfmodel::SimResult>)> =
        series.iter().map(|(n, s)| (n.as_str(), s.clone())).collect();
    let csv = report::paper::fig1_csv(&csv_series);
    csv.write_to(std::path::Path::new("runs/bench/fig1.csv")).unwrap();
    println!("\nwrote runs/bench/fig1.csv ({} rows)", csv.len());

    section("sweep cost (sim hot path)");
    let cfg = presets::paper_full_scale();
    bench("sweep_nodes(8 points, bert-120m)", 200, || {
        black_box(sweep_nodes(&cfg, &nodes));
    });
}
