//! BENCH REC5: "larger models indirectly reduce training efficiency
//! with data parallelism" — the GPU-memory model's max batch per model
//! size (paper: 184 → 20) and the resulting throughput collapse at a
//! fixed 128 nodes.
//!
//! Run: `cargo bench --bench rec5_batchsize`

use txgain::cluster::MemoryModel;
use txgain::config::presets;
use txgain::perfmodel::{simulate, MfuModel};
use txgain::report::Table;
use txgain::util::bench::{bench, black_box, section};
use txgain::util::human_bytes;

fn main() {
    section("REC 5 — model size vs batch size vs throughput @128 nodes");
    let mem = MemoryModel::new(94.0); // H100-NVL
    let mfu = MfuModel::default();

    let paper_batch = |v: &str| presets::artifact_batch(v);

    let mut t = Table::new(
        "memory model vs paper batch sizes (94 GB H100-NVL)",
        vec!["model", "params", "states", "act/sample", "max batch \
             (model)", "batch (paper)", "MFU@batch", "samples/s @128"],
    );
    for m in presets::paper_models() {
        let b_paper = paper_batch(&m.variant);
        let mut cfg = presets::paper_full_scale();
        cfg.model = m.clone();
        cfg.training.batch_per_gpu = b_paper;
        let r = simulate(&cfg);
        t.row(&[
            m.variant.clone(),
            format!("{:.0}M", m.param_count() as f64 / 1e6),
            human_bytes(mem.fixed_bytes(&m) as u64),
            human_bytes(mem.activation_bytes_per_sample(&m) as u64),
            mem.max_batch(&m).to_string(),
            b_paper.to_string(),
            format!("{:.3}", mfu.mfu(b_paper)),
            format!("{:.0}", r.samples_per_sec),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper: 120M trained at batch 184, 350M \"only managed 20\"; \
         memory model reproduces the order-of-magnitude drop (its 350M \
         estimate is looser — see EXPERIMENTS.md §REC5 discussion)\n"
    );

    // throughput ratio headline
    let tput = |variant: &str| {
        let m = presets::paper_models()
            .into_iter()
            .find(|m| m.variant == variant)
            .unwrap();
        let mut cfg = presets::paper_full_scale();
        cfg.training.batch_per_gpu = paper_batch(variant);
        cfg.model = m;
        simulate(&cfg).samples_per_sec
    };
    let t120 = tput("bert-120m");
    let t350 = tput("bert-350m");
    println!(
        "throughput @128 nodes: bert-120m {:.0} samples/s vs bert-350m \
         {:.0} samples/s ({:.1}x drop; params alone explain only ~3.1x)\n",
        t120,
        t350,
        t120 / t350
    );

    section("memory model hot path");
    let m350 = presets::model_bert_350m();
    bench("max_batch(bert-350m)", 100, || {
        black_box(mem.max_batch(&m350));
    });
}
