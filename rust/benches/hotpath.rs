//! BENCH hot path: the per-step L3 costs that must stay off the
//! critical path — batch assembly/masking, optimizer update, literal
//! conversion, the PJRT step itself (tiny + small variants), BPE
//! encode. Tracked across the perf pass (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench hotpath`

use std::sync::Arc;

use txgain::config::presets;
use txgain::data::records::Sample;
use txgain::data::{LoaderPool, Masker};
use txgain::runtime::{Engine, HostParams, Manifest};
use txgain::train::AdamW;
use txgain::util::bench::{bench, black_box, section};
use txgain::util::Rng;

fn main() {
    section("data path");
    let seq = 128usize;
    let ds: Arc<Vec<Sample>> = Arc::new(
        (0..2048)
            .map(|i| {
                let toks: Vec<u16> =
                    (0..seq - 2).map(|j| 4 + ((i + j) % 8000) as u16)
                        .collect();
                Sample::from_tokens(&toks, seq)
            })
            .collect(),
    );
    let masker = Masker::new(0.15, 8192);
    let order: Vec<u32> = (0..2048).collect();

    bench("mask one seq-128 sample", 200, || {
        let mut rng = Rng::new(3);
        black_box(masker.apply(&ds[7], &mut rng));
    });
    bench("assemble epoch: 256 batches x 8, 4 workers", 1000, || {
        let mut pool = LoaderPool::spawn(ds.clone(), seq, &order, 8,
                                         masker.clone(), 7, 0, 4, 4, 0)
            .unwrap();
        while let Some(b) = pool.next_batch() {
            black_box(&b);
        }
    });

    section("optimizer");
    let manifest = Manifest::load(&Manifest::default_dir());
    if let Ok(manifest) = &manifest {
        let meta = manifest.variant("e2e").unwrap().clone();
        let mut params = HostParams::init(&meta, 1);
        let mut opt = AdamW::new(&presets::e2e_pretrain().training,
                                 meta.grad_len);
        let grads = vec![1e-3f32; meta.grad_len];
        bench("AdamW step, 8.5M params (e2e)", 1000, || {
            opt.step(&mut params, &meta, &grads, 1e-4);
        });
        bench("HostParams::init, 8.5M params", 500, || {
            black_box(HostParams::init(&meta, 2));
        });
    }

    section("PJRT step (requires artifacts)");
    if manifest.is_ok() {
        for variant in ["tiny", "small"] {
            let engine = Engine::load(&Manifest::default_dir(), variant)
                .unwrap();
            let meta = engine.meta.clone();
            let params = HostParams::init(&meta, 1);
            let n = meta.batch * meta.seq;
            let ids: Vec<i32> =
                (0..n).map(|i| 4 + (i % (meta.vocab - 4)) as i32)
                    .collect();
            let mask = vec![1.0f32; n];
            let labels: Vec<i32> = (0..n)
                .map(|i| if i % 7 == 0 { 4 + (i % 100) as i32 }
                     else { -100 })
                .collect();
            bench(&format!("execute_step({variant}) fwd+bwd"), 3000,
                  || {
                      black_box(
                          engine
                              .execute_step(&params, &ids, &mask,
                                            &labels)
                              .unwrap(),
                      );
                  });
        }
    } else {
        println!("(skipped: run `make artifacts`)");
    }
}
