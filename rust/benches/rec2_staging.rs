//! BENCH REC2: "duplicate your dataset across nodes prior to training"
//! — prices network-direct vs local-copy staging across node counts on
//! the TX-GAIN storage model, locates the contention knee, and times the
//! real file-staging path.
//!
//! Run: `cargo bench --bench rec2_staging`

use txgain::cluster::StorageModel;
use txgain::config::{ClusterConfig, StagingPolicy};
use txgain::data::staging;
use txgain::report::Table;
use txgain::util::bench::{bench, black_box, section};
use txgain::util::human_bytes;

fn main() {
    let dataset = 25_000_000_000u64; // the paper's preprocessed 25 GB

    section("REC 2 — staging policy sweep (25 GB preprocessed dataset)");
    let mut t = Table::new(
        "per-epoch IO wall time per policy (whole-shard-set reads)",
        vec!["nodes", "net/epoch(s)", "local/epoch(s)", "net:local",
             "stage-in(s)", "break-even"],
    );
    for nodes in [1usize, 2, 4, 8, 16, 27, 32, 64, 128] {
        let c = ClusterConfig::tx_gain(nodes);
        let net =
            staging::estimate(&c, StagingPolicy::NetworkDirect, dataset);
        let loc = staging::estimate(&c, StagingPolicy::LocalCopy, dataset);
        let be = staging::break_even_epochs(&c, dataset)
            .map(|e| e.to_string())
            .unwrap_or_else(|| "never".into());
        t.row(&[
            nodes.to_string(),
            format!("{:.1}", net.per_epoch_secs),
            format!("{:.1}", loc.per_epoch_secs),
            format!("{:.1}x", net.per_epoch_secs / loc.per_epoch_secs),
            format!("{:.1}", loc.stage_in_secs),
            be,
        ]);
    }
    println!("{}", t.render());
    let c = ClusterConfig::tx_gain(128);
    let sm = StorageModel::new(&c);
    println!(
        "knee at {} concurrent readers (agg {} / client {}); past it \
         per-node Lustre bandwidth decays ~1/N\n",
        sm.saturation_nodes(),
        human_bytes((c.lustre_agg_gbs * 1e9) as u64),
        human_bytes((c.lustre_client_gbs * 1e9) as u64)
    );

    // and the un-preprocessed counterfactual the paper warns about
    let raw = 2_000_000_000_000u64;
    let net = staging::estimate(&c, StagingPolicy::NetworkDirect, raw);
    println!(
        "counterfactual without rec 1 (2 TB raw on Lustre, 128 nodes): \
         {:.0} min per epoch of pure IO\n",
        net.per_epoch_secs / 60.0
    );

    section("real staging path");
    // small real shard set staged between temp dirs
    let dir = std::env::temp_dir()
        .join(format!("txgain-rec2-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let src = dir.join("shared");
    std::fs::create_dir_all(&src).unwrap();
    let shards: Vec<_> = (0..8)
        .map(|i| {
            let p = src.join(format!("shard-{i}.bin"));
            std::fs::write(&p, vec![0u8; 1 << 20]).unwrap();
            p
        })
        .collect();
    let mut n = 0u32;
    bench("stage_local: 8 x 1 MiB shards", 400, || {
        n += 1;
        let dst = dir.join(format!("local-{n}"));
        black_box(staging::stage_local(&shards, &dst).unwrap());
        std::fs::remove_dir_all(&dst).unwrap();
    });
    bench("storage model: shared_read_time(128 nodes)", 100, || {
        let sm = StorageModel::new(&c);
        black_box(sm.shared_read_time(128, 25e9));
    });
    let _ = std::fs::remove_dir_all(&dir);
}
