//! BENCH REC1: "preprocess and tokenize the entire dataset ahead of
//! training" — measures the raw→packed size reduction on real shards at
//! several corpus sizes, extrapolates to the paper's 202M samples, and
//! times the preprocessing stages.
//!
//! Run: `cargo bench --bench rec1_preprocess`

use txgain::config::presets;
use txgain::data::corpus::CorpusGenerator;
use txgain::data::preprocess::{extrapolate_reduction, preprocess_corpus,
                               train_tokenizer};
use txgain::report::Table;
use txgain::util::bench::{bench, black_box, section};
use txgain::util::human_bytes;

fn main() {
    let base = presets::e2e_pretrain().data;

    section("REC 1 — ahead-of-time preprocessing: raw vs packed");
    let mut t = Table::new(
        "measured on real shards (synthetic corpus, paper-profile sizes)",
        vec!["samples", "raw (JSONL+hex)", "packed shards", "reduction",
             "tokens/byte"],
    );
    for samples in [256usize, 1024, 4096] {
        let mut cfg = base.clone();
        cfg.corpus_samples = samples;
        let dir = std::env::temp_dir()
            .join(format!("txgain-rec1-{}-{samples}",
                          std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let stats = preprocess_corpus(&cfg, 128, 42, &dir).unwrap();
        t.row(&[
            samples.to_string(),
            human_bytes(stats.raw_bytes),
            human_bytes(stats.tokenized_bytes),
            format!("{:.2}%", stats.reduction() * 100.0),
            format!("{:.3}", stats.tokens_per_byte),
        ]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
    println!("{}", t.render());

    // paper-scale extrapolation: 202M samples @ seq 512
    let (raw, packed) =
        extrapolate_reduction(&base, 512, 42, 202_000_000).unwrap();
    println!(
        "extrapolated to the paper's corpus (202M samples, seq 512):\n  \
         raw {} -> packed {} = {:.2}% reduction   (paper: 2 TB -> 25 GB, \
         99%)\n",
        human_bytes(raw),
        human_bytes(packed),
        (1.0 - packed as f64 / raw as f64) * 100.0
    );

    section("stage timings");
    let gen = CorpusGenerator::new(4096, base.fn_size_mu,
                                   base.fn_size_sigma, 42);
    bench("corpus: generate one ~10KB function", 200, || {
        black_box(gen.generate(17));
    });
    let tok = train_tokenizer(&gen, base.tokenizer_vocab, 48).unwrap();
    let f = gen.generate(3);
    bench("tokenizer: BPE-encode one function (heap)", 300, || {
        black_box(tok.encode(&f.bytes));
    });
    bench("tokenizer: BPE-encode one function (naive rescan)", 300, || {
        black_box(tok.encode_naive(&f.bytes));
    });
    bench("tokenizer: train (48 fns, vocab 8192)", 2000, || {
        black_box(train_tokenizer(&gen, base.tokenizer_vocab, 48)
            .unwrap());
    });
}
