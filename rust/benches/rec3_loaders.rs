//! BENCH REC3: "parallelize data loading, but only just as much as
//! necessary" — loader-count sweeps on both substrates:
//!  * modeled PyTorch-speed workers at paper scale (GPU-util knee),
//!  * the real rust LoaderPool (throughput + measured starvation).
//!
//! Run: `cargo bench --bench rec3_loaders`

use std::sync::Arc;

use txgain::config::presets;
use txgain::data::records::Sample;
use txgain::data::{LoaderPool, Masker};
use txgain::perfmodel::simulate;
use txgain::report::Table;
use txgain::util::bench::{bench, black_box, section};

fn dataset(n: usize, seq: usize) -> Arc<Vec<Sample>> {
    Arc::new(
        (0..n)
            .map(|i| {
                let toks: Vec<u16> =
                    (0..seq - 2).map(|j| 4 + ((i * 7 + j) % 250) as u16)
                        .collect();
                Sample::from_tokens(&toks, seq)
            })
            .collect(),
    )
}

fn main() {
    section("REC 3 — modeled (paper substrate: python-speed workers)");
    let mut t = Table::new(
        "bert-120m @128 nodes, batch 184/GPU",
        vec!["loaders/GPU", "fetch-exposed(ms)", "gpu-util",
             "samples/s (cluster)"],
    );
    let mut cfg = presets::paper_full_scale();
    for loaders in [1usize, 2, 4, 8, 16, 32] {
        cfg.data.loaders_per_gpu = loaders;
        let r = simulate(&cfg);
        t.row(&[
            loaders.to_string(),
            format!("{:.1}", r.loader_exposed_secs * 1e3),
            format!("{:.3}", r.gpu_util),
            format!("{:.0}", r.samples_per_sec),
        ]);
    }
    println!("{}", t.render());
    println!("knee: utilization saturates once workers cover the batch \
              prep time — \"any more ... a waste of resources\"\n");

    section("REC 3 — real rust LoaderPool (2 ms synthetic IO / batch)");
    let ds = dataset(4096, 128);
    let masker = Masker::new(0.15, 8192);
    let order: Vec<u32> = (0..4096).collect();
    let mut t = Table::new(
        "epoch of 512 batches x 8 samples",
        vec!["workers", "epoch wall(ms)", "starved wait(ms)",
             "batches/s"],
    );
    for workers in [1usize, 2, 4, 8, 16] {
        let t0 = std::time::Instant::now();
        let mut pool = LoaderPool::spawn(
            ds.clone(), 128, &order, 8, masker.clone(), 7, 0, workers, 4,
            2_000,
        )
        .unwrap();
        let mut n = 0usize;
        while let Some(b) = pool.next_batch() {
            black_box(&b);
            n += 1;
        }
        let wall = t0.elapsed().as_secs_f64();
        let waited = pool.stats.wait_ns
            .load(std::sync::atomic::Ordering::Relaxed) as f64 * 1e-9;
        t.row(&[
            workers.to_string(),
            format!("{:.0}", wall * 1e3),
            format!("{:.0}", waited * 1e3),
            format!("{:.0}", n as f64 / wall),
        ]);
    }
    println!("{}", t.render());

    section("loader hot path (no synthetic IO)");
    bench("assemble+deliver 64 batches, 4 workers", 500, || {
        let mut pool = LoaderPool::spawn(
            ds.clone(), 128, &order[..512], 8, masker.clone(), 7, 0, 4,
            4, 0,
        )
        .unwrap();
        while let Some(b) = pool.next_batch() {
            black_box(&b);
        }
    });
}
