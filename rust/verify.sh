#!/usr/bin/env bash
# Tier-1 verification wrapper: one command for CI and builders.
#
#   ./verify.sh            # fmt + build + tests + conformance + clippy
#   ./verify.sh --no-lint  # skip fmt/clippy (e.g. toolchain without it)
#
# Runs from the rust/ crate root regardless of the caller's cwd.
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" != "--no-lint" ]]; then
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --check
    else
        echo "verify.sh: rustfmt unavailable, skipping fmt check" >&2
    fi
fi

cargo build --release
cargo test -q

# the transport conformance suite, one isolated pass per backend, so a
# broken backend names itself in the failure output. (`cargo test -q`
# above already ran these once; the per-backend re-run is the explicit
# conformance gate and costs a few seconds — an acceptable overlap to
# keep the plain test pass simple and complete.)
for backend in channel shm tcp; do
    echo "verify.sh: transport conformance [${backend}]"
    cargo test -q --test integration_transport "${backend}::"
done

# the streaming-data-plane conformance suite: streaming vs in-memory
# bit-identity, mid-epoch resume, cache budget bounds (also part of
# `cargo test -q`; the explicit re-run names the data plane when it
# breaks, mirroring the transport gate above)
echo "verify.sh: data-plane conformance"
cargo test -q --test integration_data

# the async-comm-engine overlap gate: measured wall-clock exposed comm
# with the engine must not exceed the blocking baseline (world 4, shm).
# Fast (~a dozen emulated steps); exits nonzero on regression, so a
# change that quietly serializes the engine's pipeline fails CI here
echo "verify.sh: rec4 overlap smoke gate"
cargo bench --bench rec4_overlap -- --smoke

# benches/examples (including rec3_stream / stream_tuning) are not
# built by `build`/`test`; type-check them so they cannot silently rot
# out of the tier-1 gate
cargo check --release --benches --examples

if [[ "${1:-}" != "--no-lint" ]]; then
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --release --all-targets -- -D warnings
    else
        echo "verify.sh: clippy unavailable, skipping lint" >&2
    fi
fi

echo "verify.sh: OK"
