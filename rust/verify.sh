#!/usr/bin/env bash
# Tier-1 verification wrapper: one command for CI and builders.
#
#   ./verify.sh            # build + tests + clippy
#   ./verify.sh --no-lint  # skip clippy (e.g. toolchain without it)
#
# Runs from the rust/ crate root regardless of the caller's cwd.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q
# benches/examples are not built by `build`/`test`; type-check them so
# they cannot silently rot out of the tier-1 gate
cargo check --release --benches --examples

if [[ "${1:-}" != "--no-lint" ]]; then
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --release --all-targets -- -D warnings
    else
        echo "verify.sh: clippy unavailable, skipping lint" >&2
    fi
fi

echo "verify.sh: OK"
