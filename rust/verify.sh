#!/usr/bin/env bash
# Tier-1 verification wrapper: one command for CI and builders.
#
#   ./verify.sh            # fmt + build + tests + conformance + clippy
#   ./verify.sh --no-lint  # skip fmt/clippy (e.g. toolchain without it)
#
# Runs from the rust/ crate root regardless of the caller's cwd.
set -euo pipefail
cd "$(dirname "$0")"

# the manifest is tracked in-repo; a checkout without it cannot build
# anything below, so fail with a name instead of a cargo stack trace
if [[ ! -f Cargo.toml ]]; then
    echo "verify.sh: rust/Cargo.toml is missing — the crate manifest" \
         "is tracked in git and must be present to build" >&2
    exit 1
fi

if [[ "${1:-}" != "--no-lint" ]]; then
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --check
    else
        echo "verify.sh: rustfmt unavailable, skipping fmt check" >&2
    fi
fi

cargo build --release

# the concurrency-correctness gate: txgain-lint enforces the ordering
# whitelist, // ord: and // bounded: annotations, no-unwrap on
# trainer/transport paths, sim wall-clock ban, and steps.csv /
# report.json schema sync (rules documented in ../CONTRIBUTING.md).
# Hard gate: any finding fails verification.
echo "verify.sh: txgain-lint"
cargo run --release --quiet --bin txgain-lint

cargo test -q

# the interleaving model checker: exhaustive bounded exploration of the
# shm SPSC ring protocol and the dead-peer drain under simulated weak
# memory (also part of `cargo test -q`; the explicit re-run names the
# checker when a protocol change breaks it)
echo "verify.sh: interleaving model checker"
cargo test -q --test interleave_model

# dead-peer teardown stress: kill a rank mid-stream on every backend
# and require the survivor to error, not hang (watchdog-bounded)
echo "verify.sh: dead-peer teardown stress"
cargo test -q --test concurrency_stress

# the transport conformance suite, one isolated pass per backend, so a
# broken backend names itself in the failure output. (`cargo test -q`
# above already ran these once; the per-backend re-run is the explicit
# conformance gate and costs a few seconds — an acceptable overlap to
# keep the plain test pass simple and complete.) `hier` runs the
# hierarchical-collective conformance rows: bit-identity vs the flat
# ring on even and uneven groupings, per-tier wire-byte accounting
# against the cost model's schedule formula, and dead-peer teardown on
# both tiers.
for backend in channel shm tcp hier; do
    echo "verify.sh: transport conformance [${backend}]"
    cargo test -q --test integration_transport "${backend}::"
done

# the wire-codec axis: every codec (f32/bf16/int8+EF) on every backend
# — bit-identity and bounded-error contracts, measured wire bytes
# against the codec's exact byte formulas (bf16 == f32/2), dead peers
# under every encoding, engine == blocking bit-equivalence
echo "verify.sh: wire-codec conformance"
cargo test -q --test integration_transport "codec_axis::"

# the streaming-data-plane conformance suite: streaming vs in-memory
# bit-identity, mid-epoch resume, cache budget bounds (also part of
# `cargo test -q`; the explicit re-run names the data plane when it
# breaks, mirroring the transport gate above)
echo "verify.sh: data-plane conformance"
cargo test -q --test integration_data

# the process-per-rank gate: rendezvous failure modes through real
# subprocesses (error-not-hang, watchdog-bounded), the 4-process probe
# world, and — when compiled artifacts exist — the bit-identity of a
# `txgain launch` multi-process training run against the in-process
# world from the same config (also part of `cargo test -q`; the
# explicit re-run names the subsystem when it breaks)
echo "verify.sh: cross-process conformance"
cargo test -q --test integration_process

# multi-process smoke through the real CLI: spawn a 4-worker world via
# `txgain launch`. --smoke trains the quickstart-derived 4-rank config
# when artifacts exist and falls back to the transport probe when they
# don't, so the gate is meaningful on every machine within the tier-1
# time budget.
echo "verify.sh: txgain launch smoke (4 workers)"
launch_dir="$(mktemp -d "${TMPDIR:-/tmp}/txgain-launch-smoke.XXXXXX")"
trap 'rm -rf "${launch_dir}"' EXIT
target/release/txgain launch --workers 4 --smoke \
    --workdir "${launch_dir}"

# the async-comm-engine overlap gate: measured wall-clock exposed comm
# with the engine must not exceed the blocking baseline (world 4, shm),
# the hierarchical all-reduce must not expose more than the flat ring
# on the two-tier hier transport (emulated 2 nodes x 4 ranks), and the
# bf16 wire must not expose more than the f32 wire on tcp (world 4) —
# half the bytes must not cost more wall-clock. Fast (~a dozen
# emulated steps); exits nonzero on regression, so a change that
# quietly serializes the engine's pipeline — or a codec that rounds on
# the critical path — fails CI here
echo "verify.sh: rec4 overlap smoke gate"
cargo bench --bench rec4_overlap -- --smoke

# the ZeRO-2 free-on-reduce gate: at world 4 on shm, the stage-2
# schedule's measured peak gradient-plane bytes must not exceed the
# stage-1 in-place sync, must reproduce RankMemory::grad_peak_bytes
# exactly on every rank (f32 and bf16 stores), and the f32 trajectory
# must stay bit-identical to stage 1 — so a change that quietly keeps
# the full gradient resident, or drifts the measured/modeled peaks
# apart, fails CI here
echo "verify.sh: rec6 zero smoke gate"
cargo bench --bench rec6_zero -- --smoke

# benches/examples (including rec3_stream / stream_tuning) are not
# built by `build`/`test`; type-check them so they cannot silently rot
# out of the tier-1 gate
cargo check --release --benches --examples

if [[ "${1:-}" != "--no-lint" ]]; then
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --release --all-targets -- -D warnings
    else
        echo "verify.sh: clippy unavailable, skipping lint" >&2
    fi
fi

# optional ThreadSanitizer stage: checks the *real* atomics the model
# checker can only simulate. Requires a nightly toolchain (TSan is a
# -Z flag); skips with a notice when one is not installed so the plain
# gate stays runnable on stable-only machines.
if [[ "${TXGAIN_TSAN:-0}" == "1" ]]; then
    if cargo +nightly --version >/dev/null 2>&1; then
        echo "verify.sh: ThreadSanitizer pass (nightly)"
        host="$(rustc -vV | awk '/^host:/ { print $2 }')"
        RUSTFLAGS="-Zsanitizer=thread" \
            cargo +nightly test -q \
                --target "${host}" \
                --test interleave_model \
                --test concurrency_stress
    else
        echo "verify.sh: TXGAIN_TSAN=1 set but no nightly toolchain" \
             "found; skipping the ThreadSanitizer stage" >&2
    fi
fi

echo "verify.sh: OK"
