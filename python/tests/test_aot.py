"""AOT path tests: HLO text emission + manifest schema round trip."""

import json

import pytest

from compile import aot, configs, model


@pytest.fixture(scope="module")
def tiny_hlo():
    return aot.lower_variant(configs.TINY)


def test_hlo_text_structure(tiny_hlo):
    assert tiny_hlo.startswith("HloModule")
    assert "ENTRY" in tiny_hlo
    # one leaf parameter per model param + 3 batch inputs
    n_inputs = len(model.param_specs(configs.TINY)) + 3
    assert tiny_hlo.count("parameter(") >= n_inputs


def test_hlo_outputs_are_loss_and_flat_grads(tiny_hlo):
    # return_tuple=True => the entry root is a (f32[], f32[P]) tuple
    p = configs.TINY.param_count()
    assert f"f32[{p}]" in tiny_hlo


def test_manifest_schema():
    m = aot.variant_manifest(configs.TINY, "tiny.train.hlo.txt")
    js = json.loads(json.dumps(m))  # serializable
    assert js["config"]["param_count"] == configs.TINY.param_count()
    assert js["inputs"][-3:] == ["input_ids", "attn_mask", "labels"]
    assert js["outputs"] == ["loss", "flat_grads"]
    assert js["grad_len"] == configs.TINY.param_count()
    off = 0
    for p in js["params"]:
        assert p["init"].startswith(("normal:", "zeros", "ones"))
        assert p["offset"] == off
        off += p["size"]
    assert off == js["grad_len"]


def test_manifest_lists_paper_variants_without_artifacts():
    m = aot.variant_manifest(configs.BERT_350M, None)
    assert m["artifact"] is None
    assert m["config"]["param_count"] > 300e6
