"""L2 model tests: shapes, pallas-vs-ref equivalence, loss decreases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model


def _batch(cfg, seed=0, b=None):
    rng = np.random.default_rng(seed)
    b = b or cfg.artifact_batch
    ids = rng.integers(0, cfg.vocab, (b, cfg.seq))
    mask = np.ones((b, cfg.seq), np.float32)
    mask[:, cfg.seq - 4:] = 0.0  # padded tail
    labels = np.where(rng.random((b, cfg.seq)) < 0.15,
                      rng.integers(0, cfg.vocab, (b, cfg.seq)), -100)
    labels = np.where(mask > 0, labels, -100)
    return (jnp.asarray(ids, jnp.int32), jnp.asarray(mask),
            jnp.asarray(labels, jnp.int32))


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = configs.TINY
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_param_specs_count_matches_config(tiny_setup):
    cfg, params = tiny_setup
    total = sum(int(np.prod(s)) for _, s, _ in model.param_specs(cfg))
    assert total == cfg.param_count()
    assert len(params) == len(model.param_specs(cfg))


def test_all_variant_param_counts():
    # the closed-form in configs must match the actual spec shapes
    for cfg in configs.CPU_VARIANTS + configs.PAPER_VARIANTS:
        total = sum(int(np.prod(s)) for _, s, _ in model.param_specs(cfg))
        assert total == cfg.param_count(), cfg.name


def test_paper_scale_param_counts_near_reported():
    # the paper reports 120M and 350M; our configs should land close
    assert abs(configs.BERT_120M.param_count() - 120e6) / 120e6 < 0.15
    assert abs(configs.BERT_350M.param_count() - 350e6) / 350e6 < 0.15


def test_forward_hidden_shape(tiny_setup):
    cfg, params = tiny_setup
    ids, mask, _ = _batch(cfg)
    h = model.forward_hidden(cfg, params, ids, mask)
    assert h.shape == (cfg.artifact_batch, cfg.seq, cfg.hidden)
    assert bool(jnp.isfinite(h).all())


def test_pallas_and_ref_paths_agree(tiny_setup):
    cfg, params = tiny_setup
    ids, mask, labels = _batch(cfg)
    lp = model.loss_fn(cfg, params, ids, mask, labels, use_pallas=True)
    lr = model.loss_fn(cfg, params, ids, mask, labels, use_pallas=False)
    np.testing.assert_allclose(float(lp), float(lr), rtol=1e-4)


def test_train_step_outputs(tiny_setup):
    cfg, params = tiny_setup
    ids, mask, labels = _batch(cfg)
    loss, flat = model.make_train_step(cfg)(params, ids, mask, labels)
    assert loss.shape == ()
    assert flat.shape == (cfg.param_count(),)
    assert bool(jnp.isfinite(flat).all())


def test_flat_grads_order_matches_param_specs(tiny_setup):
    # slicing the flat vector by spec offsets must recover each grad
    cfg, params = tiny_setup
    ids, mask, labels = _batch(cfg)
    _, grads = jax.value_and_grad(
        lambda ps: model.loss_fn(cfg, ps, ids, mask, labels))(params)
    _, flat = model.make_train_step(cfg)(params, ids, mask, labels)
    off = 0
    for g in grads:
        n = int(np.prod(g.shape))
        np.testing.assert_allclose(np.asarray(flat[off:off + n]),
                                   np.asarray(g).reshape(-1), rtol=1e-6)
        off += n
    assert off == flat.shape[0]


def test_initial_loss_near_uniform(tiny_setup):
    # with tiny init, MLM loss should start near ln(vocab)
    cfg, params = tiny_setup
    ids, mask, labels = _batch(cfg)
    loss = float(model.loss_fn(cfg, params, ids, mask, labels))
    assert abs(loss - np.log(cfg.vocab)) < 1.0


def test_loss_decreases_under_sgd(tiny_setup):
    cfg, params = tiny_setup
    ids, mask, labels = _batch(cfg)
    step = jax.jit(model.make_train_step(cfg, use_pallas=False))
    ps = params
    losses = []
    for _ in range(8):
        loss, flat = step(ps, ids, mask, labels)
        losses.append(float(loss))
        new_ps, off = [], 0
        for p in ps:
            n = int(np.prod(p.shape))
            g = flat[off:off + n].reshape(p.shape)
            new_ps.append(p - 0.5 * g)
            off += n
        ps = tuple(new_ps)
    assert losses[-1] < losses[0] - 0.1, losses


def test_grad_wrt_padded_positions_is_zero(tiny_setup):
    # labels are -100 everywhere => loss 0 => all grads 0
    cfg, params = tiny_setup
    ids, mask, _ = _batch(cfg)
    labels = jnp.full_like(ids, -100)
    loss, flat = model.make_train_step(cfg)(params, ids, mask, labels)
    assert float(loss) == 0.0
    assert float(jnp.abs(flat).max()) == 0.0
