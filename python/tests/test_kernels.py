"""Kernel-vs-oracle correctness: the CORE L1 signal.

hypothesis sweeps shapes; every case asserts allclose against ref.py for
both forward values and (via the custom_vjp) gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import flash_attention, _pick_block
from compile.kernels.mlm_loss import mlm_loss_rows

jax.config.update("jax_enable_x64", False)


def _attn_inputs(bh, s, dh, seed, pad_frac=0.25):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(bh, s, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, s, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, s, dh)), jnp.float32)
    # key-padding mask on a suffix of positions, per (batch, head) row
    keep = (rng.random((bh, s)) > pad_frac) | (np.arange(s) == 0)
    bias = jnp.asarray(np.where(keep, 0.0, ref.NEG_INF), jnp.float32)
    return q, k, v, bias


class TestFlashAttention:
    @settings(max_examples=25, deadline=None)
    @given(
        bh=st.integers(1, 4),
        s=st.sampled_from([16, 32, 64, 128]),
        dh=st.sampled_from([8, 16, 32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_forward_matches_ref(self, bh, s, dh, seed):
        q, k, v, bias = _attn_inputs(bh, s, dh, seed)
        got = flash_attention(q, k, v, bias)
        want = ref.attention(q, k, v, bias)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("bq,bk", [(8, 8), (16, 32), (32, 16), (64, 64)])
    def test_block_shape_invariance(self, bq, bk):
        q, k, v, bias = _attn_inputs(2, 64, 16, seed=7)
        got = flash_attention(q, k, v, bias, bq, bk)
        want = ref.attention(q, k, v, bias)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_fully_masked_rows_are_finite(self):
        q, k, v, _ = _attn_inputs(1, 16, 8, seed=3, pad_frac=0.0)
        bias = jnp.full((1, 16), ref.NEG_INF, jnp.float32)
        out = flash_attention(q, k, v, bias)
        assert bool(jnp.isfinite(out).all())

    def test_gradients_match_ref_path(self):
        q, k, v, bias = _attn_inputs(2, 32, 16, seed=11)

        def scalar(fn):
            return lambda a, b, c: jnp.sum(jnp.sin(fn(a, b, c, bias)))

        g_kernel = jax.grad(scalar(flash_attention), argnums=(0, 1, 2))(
            q, k, v)
        g_ref = jax.grad(scalar(ref.attention), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g_kernel, g_ref):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)

    def test_pick_block_divides(self):
        for s in [16, 24, 48, 96, 128, 384, 512, 520]:
            b = _pick_block(s)
            assert s % b == 0 and 1 <= b <= 128


class TestMlmLoss:
    @settings(max_examples=25, deadline=None)
    @given(
        r=st.sampled_from([16, 32, 64, 256]),
        h=st.sampled_from([8, 16, 32]),
        v=st.sampled_from([32, 128, 512]),
        seed=st.integers(0, 2**16),
    )
    def test_forward_matches_ref(self, r, h, v, seed):
        rng = np.random.default_rng(seed)
        hid = jnp.asarray(rng.normal(size=(r, h)), jnp.float32)
        emb = jnp.asarray(rng.normal(size=(v, h)) * 0.05, jnp.float32)
        bias = jnp.asarray(rng.normal(size=(v,)) * 0.01, jnp.float32)
        labels = jnp.asarray(
            np.where(rng.random(r) < 0.15, rng.integers(0, v, r), -100),
            jnp.int32)
        got = mlm_loss_rows(hid, emb, bias, labels)
        want = ref.mlm_loss_rows(hid, emb, bias, labels)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_unmasked_rows_zero(self):
        rng = np.random.default_rng(0)
        hid = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
        emb = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
        bias = jnp.zeros((64,), jnp.float32)
        labels = jnp.full((32,), -100, jnp.int32)
        out = mlm_loss_rows(hid, emb, bias, labels)
        np.testing.assert_array_equal(np.asarray(out), np.zeros(32))

    def test_gradients_match_ref_path(self):
        rng = np.random.default_rng(5)
        hid = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
        emb = jnp.asarray(rng.normal(size=(128, 16)) * 0.05, jnp.float32)
        bias = jnp.zeros((128,), jnp.float32)
        labels = jnp.asarray(
            np.where(rng.random(64) < 0.3, rng.integers(0, 128, 64), -100),
            jnp.int32)

        def tot(fn):
            return lambda a, b, c: jnp.sum(fn(a, b, c, labels))

        gk = jax.grad(tot(mlm_loss_rows), argnums=(0, 1, 2))(hid, emb, bias)
        gr = jax.grad(tot(ref.mlm_loss_rows), argnums=(0, 1, 2))(
            hid, emb, bias)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-5)

    def test_loss_value_is_lse_minus_ll(self):
        # single row, hand-computed
        hid = jnp.asarray([[1.0, 0.0]], jnp.float32)
        emb = jnp.asarray([[1.0, 0.0], [0.0, 1.0]], jnp.float32)
        bias = jnp.zeros((2,), jnp.float32)
        labels = jnp.asarray([0], jnp.int32)
        out = float(mlm_loss_rows(hid, emb, bias, labels)[0])
        want = float(np.log(np.exp(1.0) + np.exp(0.0)) - 1.0)
        assert abs(out - want) < 1e-6
