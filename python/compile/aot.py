"""AOT lowering: jax train step -> HLO *text* artifacts + manifest.json.

HLO text, NOT ``lowered.compile().serialize()``: the rust side links
xla_extension 0.5.1 whose proto parser rejects jax>=0.5's 64-bit
instruction ids; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Run once by ``make artifacts``; python is never on the training hot path.
"""

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg: configs.ModelConfig) -> str:
    step = model.make_train_step(cfg, use_pallas=True)
    param_shapes = tuple(
        jax.ShapeDtypeStruct(shape, "float32")
        for _, shape, _ in model.param_specs(cfg)
    )
    batch = model.example_batch_specs(cfg)
    lowered = jax.jit(step).lower(param_shapes, *batch)
    return to_hlo_text(lowered)


def variant_manifest(cfg: configs.ModelConfig, hlo_file: str | None):
    specs = model.param_specs(cfg)
    sizes = [int(np_prod(s)) for _, s, _ in specs]
    offsets = [sum(sizes[:i]) for i in range(len(sizes))]
    return {
        "config": cfg.to_dict(),
        "artifact": hlo_file,
        "params": [
            {"name": n, "shape": list(s), "init": i, "offset": o,
             "size": sz}
            for (n, s, i), o, sz in zip(specs, offsets, sizes)
        ],
        # Flattened input order of the lowered computation:
        # all params (in order), then input_ids, attn_mask, labels.
        "inputs": [n for n, _, _ in specs] + ["input_ids", "attn_mask",
                                              "labels"],
        # Output tuple: scalar loss + one flat f32 gradient vector
        # (row-major per param, concatenated in param order).
        "outputs": ["loss", "flat_grads"],
        "grad_len": sum(sizes),
        "batch": {"size": cfg.artifact_batch, "seq": cfg.seq},
    }


def np_prod(shape):
    out = 1
    for d in shape:
        out *= d
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--paper-scale", action="store_true",
                    help="also lower the 120M-350M paper configs (slow; "
                    "compile-only sanity, not CPU-executable in reasonable "
                    "time)")
    ap.add_argument("--variants", nargs="*", default=None,
                    help="subset of variant names to build")
    args = ap.parse_args()

    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    todo = list(configs.CPU_VARIANTS)
    if args.paper_scale:
        todo += configs.PAPER_VARIANTS
    if args.variants:
        todo = [configs.ALL[v] for v in args.variants]

    manifest = {"format": "hlo-text-v1", "variants": {}}
    for cfg in todo:
        fname = f"{cfg.name}.train.hlo.txt"
        print(f"[aot] lowering {cfg.name} "
              f"({cfg.param_count() / 1e6:.1f}M params, "
              f"B={cfg.artifact_batch}, S={cfg.seq}) ...", flush=True)
        text = lower_variant(cfg)
        (outdir / fname).write_text(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        m = variant_manifest(cfg, fname)
        m["sha256_16"] = digest
        manifest["variants"][cfg.name] = m
        print(f"[aot]   wrote {fname}: {len(text)} chars, sha {digest}")

    # Paper-scale configs are always listed (rust perfmodel reads their
    # dims) even when their HLO is not built.
    for cfg in configs.PAPER_VARIANTS:
        if cfg.name not in manifest["variants"]:
            manifest["variants"][cfg.name] = variant_manifest(cfg, None)

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"[aot] manifest: {outdir / 'manifest.json'}")


if __name__ == "__main__":
    main()
