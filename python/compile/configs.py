"""Model variant definitions shared by model.py, aot.py and the tests.

Each variant is a BERT-like encoder config. The CPU-feasible variants
(tiny/small/e2e) are AOT-lowered to HLO text by aot.py; the paper-scale
configs (bert-120m .. bert-350m) exist so the rust perf model and the
python side agree on dimensions, but are not compiled for CPU execution
by default (pass --paper-scale to aot.py to emit their HLO anyway).
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    hidden: int
    layers: int
    heads: int
    seq: int
    mlp_ratio: int = 4
    # batch size baked into the AOT artifact (XLA shapes are static)
    artifact_batch: int = 8

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    def param_count(self) -> int:
        """Exact parameter count; must match rust perfmodel::flops."""
        h, v, s, l = self.hidden, self.vocab, self.seq, self.layers
        emb = v * h + s * h + 2 * h  # token + pos + emb layernorm
        per_layer = (
            4 * h * h + 4 * h  # qkv + out projections (+bias)
            + 2 * h * self.mlp_ratio * h + self.mlp_ratio * h + h  # mlp
            + 4 * h  # two layernorms
        )
        head = h * h + h + 2 * h + v  # dense + ln + output bias (tied emb)
        return emb + l * per_layer + head

    def to_dict(self):
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["param_count"] = self.param_count()
        return d


# CPU-feasible variants (AOT-compiled and executed on PJRT CPU).
TINY = ModelConfig("tiny", vocab=512, hidden=64, layers=2, heads=2, seq=64,
                   artifact_batch=4)
SMALL = ModelConfig("small", vocab=2048, hidden=128, layers=4, heads=4,
                    seq=128, artifact_batch=8)
# ~10M-param proxy for the paper's 120M model: big enough for a real loss
# curve on CPU PJRT, small enough for a few hundred steps in minutes.
E2E = ModelConfig("e2e", vocab=8192, hidden=256, layers=8, heads=8, seq=128,
                  artifact_batch=8)

# Paper-scale configs (dimensions chosen to hit the reported param counts;
# the paper gives only totals). Used by the perf model, not CPU-executed.
BERT_120M = ModelConfig("bert-120m", vocab=30000, hidden=768, layers=12,
                        heads=12, seq=512, artifact_batch=184)
BERT_180M = ModelConfig("bert-180m", vocab=30000, hidden=896, layers=16,
                        heads=14, seq=512, artifact_batch=96)
BERT_250M = ModelConfig("bert-250m", vocab=30000, hidden=1024, layers=20,
                        heads=16, seq=512, artifact_batch=48)
BERT_350M = ModelConfig("bert-350m", vocab=30000, hidden=1024, layers=24,
                        heads=16, seq=512, artifact_batch=20)

CPU_VARIANTS = [TINY, SMALL, E2E]
PAPER_VARIANTS = [BERT_120M, BERT_180M, BERT_250M, BERT_350M]
ALL = {c.name: c for c in CPU_VARIANTS + PAPER_VARIANTS}
