"""L1 Pallas kernel: tiled online-softmax (flash) attention.

TPU adaptation of the compute hot-spot (the paper trains on H100s with
stock kernels; see DESIGN.md §Hardware-Adaptation): query tiles are staged
into VMEM via BlockSpec — the role threadblock shared memory plays on the
GPU — and the S×S score matrix is never materialized in HBM; instead each
query tile runs an online-softmax accumulation over key tiles with a
fori_loop carry. Block shapes are chosen MXU-friendly (multiples of the
head dim, padded up to 128 where the variant allows).

interpret=True throughout: CPU PJRT cannot execute Mosaic custom-calls, so
the kernel lowers to plain HLO ops and the same artifact runs everywhere.
Differentiation is provided by a custom_vjp whose backward recomputes
through the pure-jnp oracle (fused forward, recompute backward).

VMEM budget per grid step (f32): q tile bq×dh + k,v S×dh each + bias S +
acc bq×dh + p bq×bk. For the paper-scale config (S=512, dh=64, bq=bk=128)
that is ≈ 0.48 MB — comfortably under the ~16 MB/core budget, leaving room
for double-buffering the next q tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_block(s: int, target: int = 128) -> int:
    """Largest divisor of s that is <= target (block shapes must tile S)."""
    b = min(s, target)
    while s % b != 0:
        b -= 1
    return b


def _fwd_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, bk: int,
                scale: float):
    # Leading grid dim of every ref is a size-1 "which (batch, head)" axis.
    q = q_ref[0] * scale                     # (bq, dh)
    bq, dh = q.shape
    s_len = k_ref.shape[1]
    nkb = s_len // bk

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(i * bk, bk), :]   # (bk, dh)
        v = v_ref[0, pl.ds(i * bk, bk), :]   # (bk, dh)
        b = bias_ref[0, pl.ds(i * bk, bk)]   # (bk,)
        s = q @ k.T + b[None, :]             # (bq, bk)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), ref.NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m0, l0, a0))
    # l >= exp(0) * 1 whenever at least one key survives masking; padded
    # query rows may divide by ~bk but are discarded downstream.
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def _flash_fwd(q, k, v, bias, *, bq: int, bk: int):
    bh, s, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    grid = (bh, s // bq)
    kernel = functools.partial(_fwd_kernel, bk=bk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda i, j: (i, j, 0)),   # q tile
            pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),    # k (full S)
            pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),    # v (full S)
            pl.BlockSpec((1, s), lambda i, j: (i, 0)),           # bias
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=True,
    )(q, k, v, bias)


@functools.lru_cache(maxsize=None)
def _make(bq, bk):
    """custom_vjp'd attention with block sizes baked in (static args)."""

    def fwd_only(q, k, v, bias):
        s = q.shape[1]
        return _flash_fwd(q, k, v, bias, bq=bq or _pick_block(s),
                          bk=bk or _pick_block(s))

    @jax.custom_vjp
    def f(q, k, v, bias):
        return fwd_only(q, k, v, bias)

    def vjp_fwd(q, k, v, bias):
        return fwd_only(q, k, v, bias), (q, k, v, bias)

    def vjp_bwd(res, do):
        q, k, v, bias = res
        # Recompute-backward through the materialized oracle: XLA fuses
        # this into the surrounding backward graph; no residuals besides
        # q/k/v/bias (the flash trade: no S×S tensor saved in fwd).
        _, vjp = jax.vjp(ref.attention, q, k, v, bias)
        return vjp(do)

    f.defvjp(vjp_fwd, vjp_bwd)
    return f


def flash_attention(q, k, v, bias, bq=None, bk=None):
    """Fused attention. q/k/v: (BH, S, dh); bias: (BH, S) additive mask."""
    return _make(bq, bk)(q, k, v, bias)
