"""L1 Pallas kernel: fused tied-projection + masked-LM cross-entropy.

The MLM head is the other memory hot-spot: materializing (B, S, V) logits
in HBM dominates activation memory at larger vocabularies. This kernel
tiles the token rows into VMEM blocks, computes the logits block against
the full embedding table resident in VMEM, and reduces straight to a
per-row loss — the (R, V) logits tensor never exists in HBM.

VMEM per grid step (f32): emb V×H + logits tile br×V + h tile br×H.
For the e2e variant (V=8192, H=256, br=128): 8 MB + 4 MB + 0.13 MB ≈ 12 MB,
inside the ~16 MB/core budget. Paper-scale vocabularies would additionally
tile V (two-pass online logsumexp); see DESIGN.md §Perf.

Backward is a custom_vjp recompute through the jnp oracle (softmax − onehot
fused by XLA); labels are non-differentiable by construction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_block(r: int, target: int = 128) -> int:
    b = min(r, target)
    while r % b != 0:
        b -= 1
    return b


def _loss_kernel(h_ref, emb_ref, bias_ref, labels_ref, out_ref):
    h = h_ref[...]                            # (br, H)
    logits = h @ emb_ref[...].T + bias_ref[...][None, :]  # (br, V)
    m = logits.max(axis=1)
    lse = m + jnp.log(jnp.exp(logits - m[:, None]).sum(axis=1))
    labels = labels_ref[...]
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    ll = jnp.take_along_axis(logits, safe[:, None], axis=1)[:, 0]
    out_ref[...] = jnp.where(valid, lse - ll, 0.0).astype(out_ref.dtype)


def _loss_fwd(h, emb, bias, labels, *, br: int):
    r, _ = h.shape
    v, hd = emb.shape
    return pl.pallas_call(
        _loss_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, hd), lambda i: (i, 0)),  # hidden rows tile
            pl.BlockSpec((v, hd), lambda i: (0, 0)),   # emb table (VMEM)
            pl.BlockSpec((v,), lambda i: (0,)),        # output bias
            pl.BlockSpec((br,), lambda i: (i,)),       # labels tile
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(h, emb, bias, labels)


@functools.lru_cache(maxsize=None)
def _make(br):
    """custom_vjp'd loss with the row-block size baked in (static arg)."""

    def fwd_only(h, emb, bias, labels):
        return _loss_fwd(h, emb, bias, labels,
                         br=br or _pick_block(h.shape[0]))

    @jax.custom_vjp
    def f(h, emb, bias, labels):
        return fwd_only(h, emb, bias, labels)

    def vjp_fwd(h, emb, bias, labels):
        return fwd_only(h, emb, bias, labels), (h, emb, bias, labels)

    def vjp_bwd(res, dout):
        h, emb, bias, labels = res
        # softmax − onehot, fused by XLA; labels are integer => no grad.
        _, vjp = jax.vjp(lambda a, b, c: ref.mlm_loss_rows(a, b, c, labels),
                         h, emb, bias)
        dh, demb, dbias = vjp(dout)
        return dh, demb, dbias, None

    f.defvjp(vjp_fwd, vjp_bwd)
    return f


def mlm_loss_rows(h, emb, bias, labels, br=None):
    """Per-row masked CE. h: (R, H); emb: (V, H); labels: (R,) int32."""
    return _make(br)(h, emb, bias, labels)
