"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite compares the kernels against,
and they also serve as the backward-pass implementations for the kernels'
custom_vjp rules (fused forward, recompute backward — the standard
flash-attention trade).
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e9  # finite "minus infinity": keeps fully-masked rows NaN-free


def attention(q, k, v, bias):
    """Multi-head scaled-dot-product attention, materialized softmax.

    q, k, v: (BH, S, dh) — batch*heads folded into the leading dim.
    bias:    (BH, S) additive key mask (0 for real tokens, NEG_INF for pad).
    returns: (BH, S, dh)
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.einsum("bqd,bkd->bqk", q * scale, k) + bias[:, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def mlm_loss_rows(h, emb, out_bias, labels):
    """Per-row masked-LM cross-entropy with a tied output projection.

    h:        (R, H) final hidden states, one row per token position.
    emb:      (V, H) tied embedding table (logits = h @ emb.T + out_bias).
    out_bias: (V,)
    labels:   (R,) int32; label < 0 means "not a masked position" => loss 0.
    returns:  (R,) f32 per-row loss (0 where label < 0).
    """
    logits = h @ emb.T + out_bias[None, :]  # (R, V)
    lse = jax.nn.logsumexp(logits, axis=-1)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    ll = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
    return jnp.where(valid, lse - ll, 0.0)
