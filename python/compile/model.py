"""L2: BERT-like MLM encoder in jax, calling the L1 Pallas kernels.

The parameter set is an *ordered tuple* of arrays — the order defined by
``param_specs`` is the contract with the rust side: aot.py writes it to
manifest.json and rust/src/train/params.rs initializes and feeds buffers
in exactly this order. No pickled pytree structure crosses the boundary.

The model is deterministic (no dropout): MLM masking is a property of the
*data pipeline* in the paper ("15% of tokens in the training dataset
randomly masked"), and lives in rust/src/data/masking.rs. The train step
is pure: (params, input_ids, attn_mask, labels) -> (loss, *grads).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.attention import flash_attention
from .kernels.mlm_loss import mlm_loss_rows


def param_specs(cfg: ModelConfig):
    """Ordered (name, shape, init) list. init ∈ normal:<std> | zeros | ones."""
    h, v, s = cfg.hidden, cfg.vocab, cfg.seq
    m = cfg.mlp_ratio * h
    specs = [
        ("tok_emb", (v, h), "normal:0.02"),
        ("pos_emb", (s, h), "normal:0.02"),
        ("emb_ln_g", (h,), "ones"),
        ("emb_ln_b", (h,), "zeros"),
    ]
    for i in range(cfg.layers):
        specs += [
            (f"l{i}.qkv_w", (h, 3 * h), "normal:0.02"),
            (f"l{i}.qkv_b", (3 * h,), "zeros"),
            (f"l{i}.out_w", (h, h), "normal:0.02"),
            (f"l{i}.out_b", (h,), "zeros"),
            (f"l{i}.ln1_g", (h,), "ones"),
            (f"l{i}.ln1_b", (h,), "zeros"),
            (f"l{i}.mlp_w1", (h, m), "normal:0.02"),
            (f"l{i}.mlp_b1", (m,), "zeros"),
            (f"l{i}.mlp_w2", (m, h), "normal:0.02"),
            (f"l{i}.mlp_b2", (h,), "zeros"),
            (f"l{i}.ln2_g", (h,), "ones"),
            (f"l{i}.ln2_b", (h,), "zeros"),
        ]
    specs += [
        ("head_w", (h, h), "normal:0.02"),
        ("head_b", (h,), "zeros"),
        ("head_ln_g", (h,), "ones"),
        ("head_ln_b", (h,), "zeros"),
        ("out_bias", (v,), "zeros"),
    ]
    return specs


def init_params(cfg: ModelConfig, key):
    """Reference initializer (tests + pure-python training sanity runs)."""
    params = []
    for name, shape, init in param_specs(cfg):
        if init.startswith("normal:"):
            std = float(init.split(":")[1])
            key, sub = jax.random.split(key)
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
        elif init == "ones":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention_block(cfg, x, attn_mask, qkv_w, qkv_b, out_w, out_b,
                     use_pallas):
    b, s, h = x.shape
    nh, dh = cfg.heads, cfg.head_dim
    qkv = x @ qkv_w + qkv_b                      # (B, S, 3H)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # (B, S, H) -> (B*nh, S, dh)
        return t.reshape(b, s, nh, dh).transpose(0, 2, 1, 3).reshape(
            b * nh, s, dh)

    bias = (1.0 - attn_mask) * ref.NEG_INF       # (B, S)
    bias = jnp.repeat(bias, nh, axis=0)          # (B*nh, S)
    attn = flash_attention if use_pallas else ref.attention
    o = attn(heads(q), heads(k), heads(v), bias)  # (B*nh, S, dh)
    o = o.reshape(b, nh, s, dh).transpose(0, 2, 1, 3).reshape(b, s, h)
    return o @ out_w + out_b


def forward_hidden(cfg: ModelConfig, params, input_ids, attn_mask,
                   use_pallas=True):
    """Embeddings + encoder stack + MLM head dense; returns (B, S, H)."""
    p = dict(zip([n for n, _, _ in param_specs(cfg)], params))
    b, s = input_ids.shape
    x = p["tok_emb"][input_ids] + p["pos_emb"][None, :s]
    x = _layernorm(x, p["emb_ln_g"], p["emb_ln_b"])
    for i in range(cfg.layers):
        a = _attention_block(cfg, x, attn_mask,
                             p[f"l{i}.qkv_w"], p[f"l{i}.qkv_b"],
                             p[f"l{i}.out_w"], p[f"l{i}.out_b"], use_pallas)
        x = _layernorm(x + a, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
        m = jax.nn.gelu(x @ p[f"l{i}.mlp_w1"] + p[f"l{i}.mlp_b1"])
        m = m @ p[f"l{i}.mlp_w2"] + p[f"l{i}.mlp_b2"]
        x = _layernorm(x + m, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
    x = jax.nn.gelu(x @ p["head_w"] + p["head_b"])
    return _layernorm(x, p["head_ln_g"], p["head_ln_b"])


def loss_fn(cfg: ModelConfig, params, input_ids, attn_mask, labels,
            use_pallas=True):
    """Mean masked-LM cross-entropy over masked positions."""
    p = dict(zip([n for n, _, _ in param_specs(cfg)], params))
    h = forward_hidden(cfg, params, input_ids, attn_mask, use_pallas)
    b, s, hd = h.shape
    rows = mlm_loss_rows if use_pallas else ref.mlm_loss_rows
    per_row = rows(h.reshape(b * s, hd), p["tok_emb"], p["out_bias"],
                   labels.reshape(b * s))
    n = jnp.maximum(jnp.sum(labels >= 0), 1).astype(jnp.float32)
    return jnp.sum(per_row) / n


def make_train_step(cfg: ModelConfig, use_pallas=True):
    """(params..., ids, mask, labels) -> (loss, flat_grads).

    Gradients are flattened (row-major) and concatenated into ONE 1-D
    f32 vector, in param_specs order. Two reasons (see rust runtime):
    1-D outputs have a unique layout, so the HLO entry layout can never
    silently transpose a gradient; and the rust side all-reduces one
    contiguous buffer instead of 30+ small ones.
    """

    def step(params, input_ids, attn_mask, labels):
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(cfg, ps, input_ids, attn_mask, labels,
                               use_pallas))(params)
        flat = jnp.concatenate([g.reshape(-1) for g in grads])
        return loss, flat

    return step


def example_batch_specs(cfg: ModelConfig):
    """ShapeDtypeStructs for (input_ids, attn_mask, labels)."""
    b, s = cfg.artifact_batch, cfg.seq
    return (
        jax.ShapeDtypeStruct((b, s), jnp.int32),
        jax.ShapeDtypeStruct((b, s), jnp.float32),
        jax.ShapeDtypeStruct((b, s), jnp.int32),
    )
